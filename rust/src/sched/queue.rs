//! Long-lived multi-tenant run queue: the serving-shaped half of the
//! scheduler (`crate::sched`).
//!
//! [`WorkerPool::run_all`](crate::sched::WorkerPool::run_all) executes
//! *finite batches*: submit everything, wait for everything. A service
//! running "many concurrent finetuning workloads" (ROADMAP north star)
//! needs the other shape — a [`RunQueue`] that accepts submissions **at
//! any time**, hands back a [`RunHandle`] the caller can `poll`, `join`,
//! or `cancel`, schedules by **priority** (higher pops first, FIFO within
//! a class), and keeps **per-tenant accounting** ([`TenantStats`]: runs,
//! steps, FF stages, FLOPs, and *exact* transfer bytes from each run's
//! own `TransferMeter`).
//!
//! # Execution model
//!
//! * **With the `xla-shared-client` feature** (pinned + audited xla rev,
//!   see `crate::sched` §Thread-safety gate): `RunQueue::new(jobs)` spawns
//!   `jobs` long-lived worker threads. Each worker pops the
//!   highest-priority, oldest submission, runs it to completion, and
//!   parks on a condvar when the queue is empty.
//! * **Without the feature** (the default): nothing xla-backed may cross
//!   a thread, so the queue spawns **no** workers. Submissions accumulate
//!   and are drained *inline*, on the thread that calls
//!   [`RunHandle::join`], strictly in priority order (FIFO within a
//!   class) — deterministic, and bit-identical to a single worker
//!   draining the same queue. `rust/tests/sched_queue.rs` asserts queue
//!   results are bit-identical to `WorkerPool::run_all` in both builds.
//!
//! # Same-artifact packing
//!
//! [`RunQueue::submit_run_packable`] opts a training run into **batched
//! group dispatch**: when its job is popped and K−1 compatible
//! submissions (same artifact, priority, step count, batch geometry,
//! and frozen-weight source — the `pack_signature`) are still queued,
//! the popped job *leads*: it claims them and drives all K runs as one
//! `*_batched{K}` program group (`crate::train::batched`), ~K× fewer
//! dispatches per step. Each member still joins its own handle with a
//! [`RunOutput`] whose losses are **bit-identical** to a solo run and
//! whose `summary.transfers` is its exact byte slice of the group
//! traffic; tenants are billed exactly as if every run went solo.
//! Ineligible specs (loss-targeted stop, FF stages, artifacts without
//! batched programs) fall back to solo execution transparently.
//!
//! # Cancellation
//!
//! [`RunHandle::cancel`] is two-phase:
//!
//! * **Queued** submissions are marked `Cancelled` immediately and are
//!   never executed — for training runs, no `Trainer` (and no device
//!   state) is ever constructed.
//! * **Running** submissions get a cooperative flag ([`CancelToken`],
//!   installed via `Trainer::set_cancel_flag`) that the policy loop
//!   checks at every step boundary: the run stops cleanly, drains its
//!   pipeline, evaluates, and reports `Cancelled` **with** its partial
//!   output — never an error, never a torn state. Members of an
//!   in-flight *batched group* have no per-step cancel point: they run
//!   to the group's end and join `Done` (cancel lands at the batch
//!   boundary).
//!
//! # Determinism and accounting
//!
//! A run's dispatch sequence depends only on its spec, never on queue
//! siblings, so queue execution is bit-identical to `run_all` for equal
//! specs at any worker count. Per-tenant transfer totals sum the per-run
//! exact meters, so across a quiescent queue they add up *exactly* to the
//! global `Runtime::stats` delta (`rust/tests/sched_queue.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};

use anyhow::Result;

use crate::runtime::{Runtime, StreamStats, TransferSnapshot};
use crate::sched::{execute_run_cancellable, lock, ArtifactCache, RunOutput, RunSpec};
use crate::train::batched::{pack_eligible, run_batched_group, MemberSpec};
use crate::train::StopRule;

/// How a job reports back to the queue: done, or cancelled-with-partial-
/// output when the job itself observed (and honored) the cooperative
/// flag. Jobs classify their *own* outcome so a racing `cancel()` that
/// landed after the work fully completed cannot misreport a delivered
/// run as cancelled — `submit_run` classifies from the trainer's
/// authoritative `summary.cancelled`; plain-closure submissions
/// ([`RunQueue::submit`]) fall back to the token state at return.
enum JobYield<R> {
    Done(R),
    Cancelled(R),
}

/// One queued job: takes the submission's [`CancelToken`] (so
/// long-running work can stop cooperatively) and returns its
/// self-classified result.
#[cfg(feature = "xla-shared-client")]
type Job<R> = Box<dyn FnOnce(&CancelToken) -> Result<JobYield<R>> + Send + 'static>;
/// Ungated variant: no worker threads exist, jobs never cross a thread,
/// so no `Send` bound (see `crate::sched`, §Thread-safety gate).
#[cfg(not(feature = "xla-shared-client"))]
type Job<R> = Box<dyn FnOnce(&CancelToken) -> Result<JobYield<R>> + 'static>;

/// The cooperative cancellation signal handed to every job. Long-running
/// jobs poll [`CancelToken::is_cancelled`] (or install
/// [`CancelToken::flag`] on a `Trainer`) and stop at their next clean
/// boundary; quick jobs may ignore it entirely.
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// The underlying shared flag (install on a
    /// `Trainer` via `set_cancel_flag` so cancellation lands at the next
    /// step boundary of the policy loop).
    pub fn flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.flag)
    }
}

/// Non-blocking status of a submission ([`RunHandle::poll`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunPoll {
    /// Waiting in the queue (not started).
    Queued,
    /// A worker is executing it.
    Running,
    /// Finished successfully; `join` will return [`RunResult::Done`].
    Done,
    /// Cancelled (before start, or cooperatively mid-run).
    Cancelled,
    /// The job returned an error; `join` will surface it.
    Failed,
}

/// What a successfully-joined submission produced.
pub enum RunResult<R = RunOutput> {
    /// Ran to completion.
    Done(R),
    /// Cancelled: `None` when the submission was cancelled before it ever
    /// started (nothing was constructed or executed), `Some` when a
    /// running job honored the cooperative flag and returned its partial
    /// output (for training runs, a consistent summary with
    /// `summary.cancelled == true`).
    Cancelled(Option<R>),
}

impl<R> RunResult<R> {
    pub fn is_cancelled(&self) -> bool {
        matches!(self, RunResult::Cancelled(_))
    }

    /// The completed output, if the run finished normally.
    pub fn done(self) -> Option<R> {
        match self {
            RunResult::Done(r) => Some(r),
            RunResult::Cancelled(_) => None,
        }
    }

    /// Whatever output exists — complete, or the partial output of a
    /// cooperative mid-run cancellation.
    pub fn into_output(self) -> Option<R> {
        match self {
            RunResult::Done(r) => Some(r),
            RunResult::Cancelled(r) => r,
        }
    }
}

/// Per-tenant accounting, updated as the tenant's submissions move
/// through the queue. Counters (`submitted`/`completed`/…) are maintained
/// by the queue itself; the per-run fields (`adam_steps`, `flops`,
/// `transfers`, …) are folded in by training-run submissions
/// ([`RunQueue::submit_run`]) from each run's own summary — `transfers`
/// sums the runs' **exact** per-engine meters, so tenant byte totals add
/// up exactly to the global `Runtime::stats` delta across a quiescent
/// queue whose runs all completed or were cancelled. (A *failed* run has
/// no summary to fold: its partial traffic stays in the global meters
/// only, and `failed` counts it.)
#[derive(Debug, Default, Clone)]
pub struct TenantStats {
    pub submitted: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub failed: u64,
    /// Adam steps across the tenant's finished runs (cancelled runs
    /// included — their partial work is real work).
    pub adam_steps: u64,
    /// FF simulated steps across the tenant's finished runs.
    pub sim_steps: u64,
    /// FF stages executed across the tenant's finished runs.
    pub ff_stages: u64,
    /// Chargeable FLOPs across the tenant's finished runs.
    pub flops: u64,
    /// Wall-clock seconds its runs occupied workers.
    pub seconds: f64,
    /// Exact host↔device traffic of the tenant's finished runs (sum of
    /// per-run `TransferMeter`s).
    pub transfers: TransferSnapshot,
}

enum Outcome<R> {
    Done(R),
    Cancelled(Option<R>),
    Failed(anyhow::Error),
}

enum HandleState<R> {
    Queued,
    Running,
    /// `None` once [`RunHandle::join`] took the outcome (join consumes
    /// the handle, so nothing can observe this afterwards).
    Finished(Option<Outcome<R>>),
}

/// Shared between a [`RunHandle`] and the queue: one per submission.
struct HandleShared<R> {
    seq: u64,
    tenant: String,
    cancel: Arc<AtomicBool>,
    state: Mutex<HandleState<R>>,
    cv: Condvar,
}

struct Entry<R> {
    job: Job<R>,
    handle: Arc<HandleShared<R>>,
}

/// What a pack leader needs to run a claimed sibling's member: its spec
/// and tenant (for accounting). Parked in [`Shared::pack_pool`] by
/// [`RunQueue::submit_run_packable`] until the submission's own job
/// takes it back (solo) or a leader claims it (batched).
struct PackData {
    spec: RunSpec,
    tenant: String,
}

/// A packable submission parked for group formation. The `data` slot is
/// the exclusivity token: whoever takes the `PackData` — the
/// submission's own job, or a pack leader that flipped its handle
/// `Queued → Running` first — owns the run. Slots found empty (or
/// handles found past `Queued`) are stale and dropped from the pool.
struct PackMate<R> {
    handle: Arc<HandleShared<R>>,
    data: Arc<Mutex<Option<PackData>>>,
}

struct QueueState<R> {
    /// priority class → submissions, oldest first. Pop = highest class,
    /// front of its deque; empty classes are removed eagerly.
    ready: BTreeMap<i32, VecDeque<Entry<R>>>,
    /// Entries currently in `ready` (including submissions cancelled
    /// while queued that no worker has reaped yet).
    queued: usize,
    next_seq: u64,
    paused: bool,
    shutdown: bool,
}

struct Shared<R> {
    state: Mutex<QueueState<R>>,
    /// Workers (and pause/shutdown transitions) wait/notify here.
    cv: Condvar,
    tenants: Mutex<BTreeMap<String, TenantStats>>,
    /// Packable submissions awaiting group formation, keyed by pack
    /// signature (artifact | priority | steps | batch geometry | frozen
    /// source — see `pack_signature`). Lock order: `pack_pool` before
    /// any `HandleShared::state`, never the other way.
    pack_pool: Mutex<BTreeMap<String, Vec<PackMate<R>>>>,
}

/// Plain-closure cancel classification ([`RunQueue::submit`]): the best
/// signal a generic job has is the token state at return. Jobs with an
/// authoritative marker of their own (training runs: `summary.cancelled`)
/// build the [`JobYield`] themselves instead.
fn yield_by_token<R>(out: R, token: &CancelToken) -> Result<JobYield<R>> {
    if token.is_cancelled() {
        Ok(JobYield::Cancelled(out))
    } else {
        Ok(JobYield::Done(out))
    }
}

/// Render a caught panic payload as the submission's error (the common
/// payloads are `&str`/`String` from panic!/assert!/expect).
fn panic_error(payload: Box<dyn std::any::Any + Send>) -> anyhow::Error {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string());
    anyhow::anyhow!("queued job panicked: {msg}")
}

/// Pop the next runnable entry: highest priority class, FIFO within it.
/// Submissions cancelled while still queued are reaped (dropped
/// unexecuted) here. Returns `None` when paused or empty.
fn take_next<R>(st: &mut QueueState<R>) -> Option<Entry<R>> {
    if st.paused {
        return None;
    }
    loop {
        let prio = *st.ready.keys().next_back()?;
        let class = st.ready.get_mut(&prio).expect("key just observed");
        let entry = class.pop_front().expect("empty classes are removed");
        if class.is_empty() {
            st.ready.remove(&prio);
        }
        st.queued -= 1;
        let finished = matches!(&*lock(&entry.handle.state), HandleState::Finished(_));
        if finished {
            continue; // cancelled while queued: never execute
        }
        return Some(entry);
    }
}

/// Execute one popped entry to completion and publish its outcome. Shared
/// by the gated worker threads and the ungated inline drain, so both
/// builds run the same state machine.
fn run_entry<R>(shared: &Shared<R>, entry: Entry<R>) {
    let handle = entry.handle;
    {
        let mut st = lock(&handle.state);
        match *st {
            // cancel raced the pop: treated as cancel-before-start
            HandleState::Finished(_) => return,
            // a pack leader claimed this submission out of the pool
            // (`submit_run_packable`): the leader owns it now — it will
            // publish the outcome; the queue entry is just a husk. Only
            // the leader's claim ever sets Running outside this function,
            // and only on entries whose job reads its spec from the pack
            // slot, so the dropped `entry.job` loses nothing.
            HandleState::Running => return,
            HandleState::Queued => *st = HandleState::Running,
        }
    }
    let token = CancelToken { flag: Arc::clone(&handle.cancel) };
    // The job classifies its own outcome (see [`JobYield`]): a cancel
    // honored mid-run comes back Cancelled with the partial output; a
    // cancel that raced a fully-completed job stays Done. A *panicking*
    // job must not unwind past here — it would kill the worker with the
    // handle stuck at Running, hanging every joiner forever (the pool's
    // scoped threads re-raise at scope exit; a long-lived queue has no
    // scope exit) — so the unwind is caught and reported as a failure.
    let job = entry.job;
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&token)));
    let outcome = match caught {
        Err(payload) => Outcome::Failed(panic_error(payload)),
        Ok(Err(e)) => Outcome::Failed(e),
        Ok(Ok(JobYield::Cancelled(out))) => Outcome::Cancelled(Some(out)),
        Ok(Ok(JobYield::Done(out))) => Outcome::Done(out),
    };
    {
        let mut tenants = lock(&shared.tenants);
        let t = tenants.entry(handle.tenant.clone()).or_default();
        match &outcome {
            Outcome::Done(_) => t.completed += 1,
            Outcome::Cancelled(_) => t.cancelled += 1,
            Outcome::Failed(_) => t.failed += 1,
        }
    }
    let mut st = lock(&handle.state);
    *st = HandleState::Finished(Some(outcome));
    drop(st);
    handle.cv.notify_all();
}

#[cfg(feature = "xla-shared-client")]
fn worker_loop<R: Send + 'static>(shared: &Shared<R>) {
    loop {
        let entry = {
            let mut st = lock(&shared.state);
            loop {
                if let Some(e) = take_next(&mut st) {
                    break Some(e);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        match entry {
            Some(e) => run_entry(shared, e),
            None => return,
        }
    }
}

/// The long-lived submission queue (see module docs). Generic over the
/// job result `R` so the scheduling/handle machinery is exercised by
/// plain closures in unit tests; training runs use `R = `[`RunOutput`]
/// via [`RunQueue::submit_run`].
pub struct RunQueue<R = RunOutput> {
    shared: Arc<Shared<R>>,
    /// Worker threads actually spawned: `jobs` with the
    /// `xla-shared-client` feature, 0 without it (inline drain on join).
    workers: usize,
    #[cfg(feature = "xla-shared-client")]
    threads: Vec<std::thread::JoinHandle<()>>,
}

fn new_shared<R>(paused: bool) -> Arc<Shared<R>> {
    Arc::new(Shared {
        state: Mutex::new(QueueState {
            ready: BTreeMap::new(),
            queued: 0,
            next_seq: 0,
            paused,
            shutdown: false,
        }),
        cv: Condvar::new(),
        tenants: Mutex::new(BTreeMap::new()),
        pack_pool: Mutex::new(BTreeMap::new()),
    })
}

#[cfg(feature = "xla-shared-client")]
impl<R: Send + 'static> RunQueue<R> {
    /// A queue draining on `jobs` long-lived worker threads (clamped to
    /// at least 1).
    pub fn new(jobs: usize) -> RunQueue<R> {
        Self::build(jobs, false)
    }

    /// A queue whose workers hold until [`RunQueue::release`] — lets a
    /// caller submit a cold backlog and observe pure priority order.
    pub fn new_paused(jobs: usize) -> RunQueue<R> {
        Self::build(jobs, true)
    }

    fn build(jobs: usize, paused: bool) -> RunQueue<R> {
        let shared = new_shared(paused);
        let workers = jobs.max(1);
        let threads = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared.as_ref()))
            })
            .collect();
        RunQueue { shared, workers, threads }
    }
}

#[cfg(not(feature = "xla-shared-client"))]
impl<R: 'static> RunQueue<R> {
    /// Without the `xla-shared-client` feature no worker threads exist
    /// (nothing xla-backed may cross a thread — see `crate::sched`,
    /// §Thread-safety gate): submissions queue up and execute inline, in
    /// priority order, on the thread that calls [`RunHandle::join`].
    /// Same results, same ordering contract, no wall-clock overlap;
    /// `jobs` is accepted for CLI symmetry and ignored.
    pub fn new(jobs: usize) -> RunQueue<R> {
        let _ = jobs;
        Self::build(false)
    }

    /// Paused variant of [`RunQueue::new`]; [`RunQueue::release`] opens
    /// the queue for the inline drain.
    pub fn new_paused(jobs: usize) -> RunQueue<R> {
        let _ = jobs;
        Self::build(true)
    }

    fn build(paused: bool) -> RunQueue<R> {
        RunQueue { shared: new_shared(paused), workers: 0 }
    }
}

impl<R: 'static> RunQueue<R> {
    /// Submit one job under a tenant at a priority; returns immediately
    /// with the submission's [`RunHandle`]. Higher priorities pop first;
    /// equal priorities are FIFO. If the job returns with its cancel
    /// token raised, it joins as `Cancelled` with the (partial) output.
    #[cfg(feature = "xla-shared-client")]
    pub fn submit<F>(&self, tenant: &str, priority: i32, job: F) -> RunHandle<R>
    where
        F: FnOnce(&CancelToken) -> Result<R> + Send + 'static,
    {
        self.submit_boxed(tenant, priority, Box::new(move |t| yield_by_token(job(t)?, t)))
    }

    /// Submit one job under a tenant at a priority (inline-drain build:
    /// no `Send` bound — the job never crosses a thread). Cancel
    /// classification as in the gated variant.
    #[cfg(not(feature = "xla-shared-client"))]
    pub fn submit<F>(&self, tenant: &str, priority: i32, job: F) -> RunHandle<R>
    where
        F: FnOnce(&CancelToken) -> Result<R> + 'static,
    {
        self.submit_boxed(tenant, priority, Box::new(move |t| yield_by_token(job(t)?, t)))
    }

    fn submit_boxed(&self, tenant: &str, priority: i32, job: Job<R>) -> RunHandle<R> {
        let handle = {
            let mut st = lock(&self.shared.state);
            let handle = Arc::new(HandleShared {
                seq: st.next_seq,
                tenant: tenant.to_string(),
                cancel: Arc::new(AtomicBool::new(false)),
                state: Mutex::new(HandleState::Queued),
                cv: Condvar::new(),
            });
            st.next_seq += 1;
            st.ready
                .entry(priority)
                .or_default()
                .push_back(Entry { job, handle: Arc::clone(&handle) });
            st.queued += 1;
            handle
        };
        lock(&self.shared.tenants).entry(tenant.to_string()).or_default().submitted += 1;
        self.shared.cv.notify_one();
        RunHandle { handle, shared: Arc::clone(&self.shared) }
    }

    /// Open a paused queue ([`RunQueue::new_paused`]). No-op otherwise.
    pub fn release(&self) {
        lock(&self.shared.state).paused = false;
        self.shared.cv.notify_all();
    }

    /// Submissions still in the queue structure (not yet picked up;
    /// includes queued-then-cancelled entries no worker has reaped yet).
    pub fn pending(&self) -> usize {
        lock(&self.shared.state).queued
    }

    /// Worker threads this queue actually spawned (0 = inline drain; see
    /// [`RunQueue::new`] in builds without the thread-safety feature).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Point-in-time copy of every tenant's accounting.
    pub fn tenants(&self) -> BTreeMap<String, TenantStats> {
        lock(&self.shared.tenants).clone()
    }

    /// One tenant's accounting (default-zero if it never submitted).
    pub fn tenant(&self, name: &str) -> TenantStats {
        lock(&self.shared.tenants).get(name).cloned().unwrap_or_default()
    }
}

/// Fold one finished run's per-run accounting into its tenant (steps,
/// FLOPs, wall-clock, and the run's **exact** transfer meter).
fn fold_run_stats(shared: &Shared<RunOutput>, tenant: &str, out: &RunOutput) {
    let mut tenants = lock(&shared.tenants);
    let t = tenants.entry(tenant.to_string()).or_default();
    t.adam_steps += out.summary.adam_steps as u64;
    t.sim_steps += out.summary.sim_steps as u64;
    t.ff_stages += out.stages.len() as u64;
    t.flops += out.summary.flops.total();
    t.seconds += out.seconds;
    t.transfers = t.transfers.plus(&out.summary.transfers);
}

/// The pack key two submissions must share to ride one batched dispatch:
/// same artifact (same programs and batch geometry), same priority (the
/// leader must not pull work ahead of its class), same step count
/// (members stay in lock-step to the end), same eval-set size (final
/// eval chunks stack), same `global_batch`, and the same frozen-weight
/// source — a shared base checkpoint (by identity) or an equal seed,
/// since `init_params` derives the frozen base from the seed and the
/// batched programs share one unstacked base across the group
/// (`run_batched_group` re-verifies this bitwise at claim time).
///
/// `None` means the spec can never pack (loss-targeted stop rule or FF
/// stages) and should be submitted solo.
fn pack_signature(spec: &RunSpec, priority: i32) -> Option<String> {
    let steps = match &spec.stop {
        StopRule::MaxSteps(n) => *n,
        _ => return None,
    };
    if spec.cfg.ff.enabled {
        return None;
    }
    let frozen_src = match &spec.base {
        Some(b) => format!("base:{:p}", Arc::as_ptr(b)),
        None => format!("seed:{}", spec.cfg.seed),
    };
    Some(format!(
        "{}|p{priority}|n{steps}|gb{}|te{}|{frozen_src}",
        spec.cfg.artifact, spec.cfg.global_batch, spec.cfg.test_examples
    ))
}

/// Drop one mate (identified by its slot) from the pack pool, if it is
/// still registered.
fn unregister_mate<R>(shared: &Shared<R>, sig: &str, slot: &Arc<Mutex<Option<PackData>>>) {
    let mut pool = lock(&shared.pack_pool);
    if let Some(list) = pool.get_mut(sig) {
        list.retain(|m| !Arc::ptr_eq(&m.data, slot));
        if list.is_empty() {
            pool.remove(sig);
        }
    }
}

/// Publish a claimed sibling's outcome: tenant counters first (matching
/// [`run_entry`]'s order), then the terminal state, then wake joiners.
fn publish_mate(
    shared: &Shared<RunOutput>,
    handle: &Arc<HandleShared<RunOutput>>,
    outcome: Outcome<RunOutput>,
) {
    {
        let mut tenants = lock(&shared.tenants);
        let t = tenants.entry(handle.tenant.clone()).or_default();
        match &outcome {
            Outcome::Done(_) => t.completed += 1,
            Outcome::Cancelled(_) => t.cancelled += 1,
            Outcome::Failed(_) => t.failed += 1,
        }
    }
    *lock(&handle.state) = HandleState::Finished(Some(outcome));
    handle.cv.notify_all();
}

/// Run one member solo (the no-mates fallback and the odd-size
/// remainder of a pack), folding its stats and classifying from the
/// trainer's authoritative `summary.cancelled`.
fn run_solo_member(
    rt: &Arc<Runtime>,
    artifacts: &ArtifactCache,
    shared: &Shared<RunOutput>,
    data: PackData,
    cancel: Option<Arc<AtomicBool>>,
) -> Result<JobYield<RunOutput>> {
    let PackData { spec, tenant } = data;
    let out = execute_run_cancellable(rt, artifacts, spec, cancel)?;
    fold_run_stats(shared, &tenant, &out);
    // The trainer's summary is the authoritative cancel marker: a
    // cancel that raced a fully-delivered run stays Done (and bills as
    // completed), not Cancelled.
    if out.summary.cancelled {
        Ok(JobYield::Cancelled(out))
    } else {
        Ok(JobYield::Done(out))
    }
}

impl RunQueue<RunOutput> {
    /// Submit one whole training run: the `Trainer` is constructed and
    /// driven on whichever worker pops the submission (inline at `join`
    /// in gated-off builds), with the handle's cancel flag installed so
    /// [`RunHandle::cancel`] lands at the next step boundary. The
    /// tenant's [`TenantStats`] are folded in from the run's summary when
    /// it finishes — including the run's **exact** per-engine transfer
    /// bytes.
    pub fn submit_run(
        &self,
        rt: &Arc<Runtime>,
        artifacts: &Arc<ArtifactCache>,
        spec: RunSpec,
        priority: i32,
        tenant: &str,
    ) -> RunHandle<RunOutput> {
        let rt = Arc::clone(rt);
        let artifacts = Arc::clone(artifacts);
        let shared = Arc::clone(&self.shared);
        let tenant_name = tenant.to_string();
        self.submit_boxed(
            tenant,
            priority,
            Box::new(move |token: &CancelToken| {
                let data = PackData { spec, tenant: tenant_name };
                run_solo_member(&rt, &artifacts, &shared, data, Some(token.flag()))
            }),
        )
    }

    /// Like [`RunQueue::submit_run`], but opted into **same-artifact
    /// packing**: when this submission reaches the front of the queue
    /// and K−1 compatible submissions (same [`pack_signature`]) are
    /// still waiting behind it, the popped job becomes the *pack
    /// leader* — it claims them out of the queue and drives all K runs
    /// as one `*_batched{K}` program group (2 dispatches per step for
    /// the whole group — see `rust/src/train/batched.rs`), then
    /// publishes every member's [`RunOutput`] to its own handle.
    ///
    /// The contract is unchanged from solo submission: each member's
    /// per-step losses and final test loss are **bit-identical** to
    /// running it alone, its `summary.transfers` is its exact byte
    /// slice of the group traffic, and its tenant is billed exactly as
    /// if it ran solo. Cancellation changes granularity only: a queued
    /// cancel still prevents execution, but once a group is in flight
    /// its members run to the end of the group (cancel lands at the
    /// batch boundary, `docs/step-pipeline.md`).
    ///
    /// Specs that can never pack (loss-targeted stop, FF stages) or
    /// whose artifact ships no batched programs fall back to solo
    /// execution automatically.
    pub fn submit_run_packable(
        &self,
        rt: &Arc<Runtime>,
        artifacts: &Arc<ArtifactCache>,
        spec: RunSpec,
        priority: i32,
        tenant: &str,
    ) -> RunHandle<RunOutput> {
        let sig = match pack_signature(&spec, priority) {
            Some(sig) => sig,
            None => return self.submit_run(rt, artifacts, spec, priority, tenant),
        };
        let rt = Arc::clone(rt);
        let artifacts = Arc::clone(artifacts);
        let shared = Arc::clone(&self.shared);
        let slot = Arc::new(Mutex::new(Some(PackData {
            spec,
            tenant: tenant.to_string(),
        })));
        let job = {
            let (sig, slot) = (sig.clone(), Arc::clone(&slot));
            Box::new(move |token: &CancelToken| {
                lead_or_run_solo(&rt, &artifacts, &shared, &sig, &slot, token)
            })
        };
        let handle = self.submit_boxed(tenant, priority, job);
        // Register for claiming *after* submission (the handle must
        // exist first). If a worker already popped and ran the job in
        // between, the slot is empty and the registration is a stale
        // husk future leaders drop on sight.
        lock(&self.shared.pack_pool)
            .entry(sig)
            .or_default()
            .push(PackMate { handle: Arc::clone(&handle.handle), data: slot });
        handle
    }
}

/// The body of a packable submission's job: reclaim the spec from the
/// pack slot, then either lead a batched group over compatible waiting
/// submissions or fall back to solo execution.
fn lead_or_run_solo(
    rt: &Arc<Runtime>,
    artifacts: &Arc<ArtifactCache>,
    shared: &Arc<Shared<RunOutput>>,
    sig: &str,
    slot: &Arc<Mutex<Option<PackData>>>,
    token: &CancelToken,
) -> Result<JobYield<RunOutput>> {
    // Exclusive by the Queued→Running transition run_entry just made:
    // leaders only claim slots of still-Queued handles, so our own slot
    // is necessarily intact here.
    let own = lock(slot)
        .take()
        .ok_or_else(|| anyhow::anyhow!("pack slot emptied while queued (claim protocol bug)"))?;
    unregister_mate(shared.as_ref(), sig, slot);

    let art = artifacts.load(rt, &own.spec.cfg.artifact)?;
    if !pack_eligible(&art.manifest, &own.spec.cfg, &own.spec.stop) {
        return run_solo_member(rt, artifacts, shared, own, Some(token.flag()));
    }
    let steps = match &own.spec.stop {
        StopRule::MaxSteps(n) => *n,
        _ => unreachable!("pack_signature admits MaxSteps only"),
    };
    let sizes = art.manifest.batched_group_sizes();
    let max_r = *sizes.last().expect("pack_eligible implies batched programs");

    // Claim compatible waiting submissions, oldest first, up to the
    // largest emitted group size. A claim flips the sibling's handle
    // Queued → Running under its state lock — the same transition
    // run_entry makes — so each submission is owned exactly once no
    // matter which side gets there first.
    let mut members = vec![own];
    let mut claimed: Vec<Arc<HandleShared<RunOutput>>> = Vec::new();
    {
        let mut pool = lock(&shared.pack_pool);
        if let Some(list) = pool.get_mut(sig) {
            let mut kept = Vec::new();
            for mate in list.drain(..) {
                if members.len() >= max_r {
                    kept.push(mate);
                    continue;
                }
                let mut st = lock(&mate.handle.state);
                if !matches!(*st, HandleState::Queued) {
                    // cancelled while queued, or already running solo:
                    // drop the stale pool entry, never execute it here
                    continue;
                }
                match lock(&mate.data).take() {
                    Some(d) => {
                        *st = HandleState::Running;
                        drop(st);
                        members.push(d);
                        claimed.push(Arc::clone(&mate.handle));
                    }
                    None => {} // stale husk (job already ran): drop
                }
            }
            if kept.is_empty() {
                pool.remove(sig);
            } else {
                *list = kept;
            }
        }
    }

    // The group runs at the largest emitted size we filled; members
    // beyond it (odd remainders, e.g. 3 claimed with sizes {2, 4}) run
    // solo on this same worker rather than being released — a released
    // entry's queue slot may already have been reaped, which would
    // strand its joiner forever.
    let group_r = sizes.iter().rev().find(|&&r| r <= members.len()).copied();
    let group_r = match group_r {
        Some(r) => r,
        None => {
            // nobody to pack with (sizes start at 2): plain solo run
            debug_assert!(claimed.is_empty());
            let own = members.pop().expect("leader is always present");
            return run_solo_member(rt, artifacts, shared, own, Some(token.flag()));
        }
    };
    let remainder: Vec<PackData> = members.split_off(group_r);
    let rem_handles: Vec<Arc<HandleShared<RunOutput>>> = claimed.split_off(group_r - 1);

    let specs: Vec<MemberSpec> = members
        .iter()
        .map(|d| MemberSpec {
            label: d.spec.label.clone(),
            cfg: d.spec.cfg.clone(),
            base: d.spec.base.clone(),
        })
        .collect();
    let group = run_batched_group(rt, &art, &specs, steps);

    let own_yield = match group {
        Err(e) => {
            // Every claimed handle — packed or remainder — fails with
            // the group: their joiners must not hang on a husk.
            let msg = format!("{e:#}");
            for h in claimed.iter().chain(&rem_handles) {
                publish_mate(
                    shared.as_ref(),
                    h,
                    Outcome::Failed(anyhow::anyhow!("batched group failed: {msg}")),
                );
            }
            return Err(e.context("batched group"));
        }
        Ok(outs) => {
            let mut own_yield = None;
            for (i, (m, d)) in outs.into_iter().zip(members.iter()).enumerate() {
                let out = RunOutput {
                    label: m.label,
                    summary: m.summary,
                    stream: StreamStats::default(),
                    sgd_losses: m.sgd_losses,
                    stages: Vec::new(),
                    seconds: m.seconds,
                };
                fold_run_stats(shared.as_ref(), &d.tenant, &out);
                if i == 0 {
                    own_yield = Some(JobYield::Done(out));
                } else {
                    publish_mate(shared.as_ref(), &claimed[i - 1], Outcome::Done(out));
                }
            }
            own_yield.expect("group returns one output per member")
        }
    };

    // Odd remainder: run each claimed-but-unpacked member solo right
    // here, honoring its own cancel flag, and publish to its handle.
    for (d, h) in remainder.into_iter().zip(rem_handles) {
        let cancel = Some(Arc::clone(&h.cancel));
        match run_solo_member(rt, artifacts, shared.as_ref(), d, cancel) {
            Ok(JobYield::Done(out)) => publish_mate(shared.as_ref(), &h, Outcome::Done(out)),
            Ok(JobYield::Cancelled(out)) => {
                publish_mate(shared.as_ref(), &h, Outcome::Cancelled(Some(out)))
            }
            Err(e) => publish_mate(shared.as_ref(), &h, Outcome::Failed(e)),
        }
    }
    Ok(own_yield)
}

impl<R> Drop for RunQueue<R> {
    /// Shutting the queue down cancels everything still queued (so
    /// joiners can never hang on work nobody will run), lets in-flight
    /// jobs finish, and joins the workers.
    fn drop(&mut self) {
        let leftovers: Vec<Entry<R>> = {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            st.paused = false;
            let mut out = Vec::new();
            while let Some((_, mut class)) = st.ready.pop_last() {
                while let Some(e) = class.pop_front() {
                    st.queued -= 1;
                    out.push(e);
                }
            }
            out
        };
        self.shared.cv.notify_all();
        for e in leftovers {
            let mut st = lock(&e.handle.state);
            if !matches!(*st, HandleState::Queued) {
                // already individually cancelled — or a husk entry whose
                // submission a pack leader claimed (Running): the leader
                // publishes its real outcome, so shutdown must not
                // clobber it with Cancelled(None).
                continue;
            }
            *st = HandleState::Finished(Some(Outcome::Cancelled(None)));
            drop(st);
            lock(&self.shared.tenants)
                .entry(e.handle.tenant.clone())
                .or_default()
                .cancelled += 1;
            e.handle.cv.notify_all();
        }
        #[cfg(feature = "xla-shared-client")]
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The caller's side of one submission: poll it, cancel it, or join it.
/// Not cloneable — exactly one owner may consume the result.
pub struct RunHandle<R = RunOutput> {
    handle: Arc<HandleShared<R>>,
    shared: Arc<Shared<R>>,
}

impl<R: 'static> RunHandle<R> {
    /// Submission sequence number (global, monotone): the tiebreak order
    /// within a priority class, and the index [`join_all`] reports the
    /// first error by.
    pub fn seq(&self) -> u64 {
        self.handle.seq
    }

    pub fn tenant(&self) -> &str {
        &self.handle.tenant
    }

    /// Non-blocking status. Never executes work — in inline-drain builds
    /// a queued submission stays `Queued` until something `join`s.
    pub fn poll(&self) -> RunPoll {
        match &*lock(&self.handle.state) {
            HandleState::Queued => RunPoll::Queued,
            HandleState::Running => RunPoll::Running,
            HandleState::Finished(Some(Outcome::Done(_))) => RunPoll::Done,
            HandleState::Finished(Some(Outcome::Cancelled(_))) => RunPoll::Cancelled,
            HandleState::Finished(Some(Outcome::Failed(_))) => RunPoll::Failed,
            // join consumed the outcome — unobservable, since join also
            // consumes the handle; report the terminal state.
            HandleState::Finished(None) => RunPoll::Done,
        }
    }

    /// Request cancellation. A submission still **queued** is marked
    /// `Cancelled` immediately and will never execute (for training
    /// runs: no `Trainer` is ever constructed). A **running** submission
    /// keeps running until its next step boundary — the cooperative flag
    /// is the only signal; nothing is torn down mid-step.
    pub fn cancel(&self) {
        self.handle.cancel.store(true, Ordering::SeqCst);
        let mut st = lock(&self.handle.state);
        if matches!(*st, HandleState::Queued) {
            *st = HandleState::Finished(Some(Outcome::Cancelled(None)));
            drop(st);
            lock(&self.shared.tenants)
                .entry(self.handle.tenant.clone())
                .or_default()
                .cancelled += 1;
            self.handle.cv.notify_all();
        }
    }

    /// Block until the submission finishes and return its outcome.
    /// Job errors come back as `Err` with the submission index attached;
    /// cancellation is a normal [`RunResult::Cancelled`], never an error.
    ///
    /// In builds without the thread-safety feature this is also the drain
    /// pump: joining executes queued submissions inline, in priority
    /// order, until this one has finished (see module docs). Joining a
    /// still-**paused** queue there is an error, not a hang: no workers
    /// exist, so nothing could ever run the submission — call
    /// [`RunQueue::release`] first.
    pub fn join(self) -> Result<RunResult<R>> {
        self.drive_inline()?;
        let mut st = lock(&self.handle.state);
        loop {
            if let HandleState::Finished(slot) = &mut *st {
                let outcome = slot.take().expect("join consumes the only handle");
                return match outcome {
                    Outcome::Done(r) => Ok(RunResult::Done(r)),
                    Outcome::Cancelled(r) => Ok(RunResult::Cancelled(r)),
                    Outcome::Failed(e) => {
                        Err(e.context(format!("queued run #{}", self.handle.seq)))
                    }
                };
            }
            st = self.handle.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    #[cfg(feature = "xla-shared-client")]
    fn drive_inline(&self) -> Result<()> {
        Ok(())
    }

    /// No workers exist in this build: drain ready submissions — highest
    /// priority first, FIFO within a class — on this thread until the
    /// joined one finishes. A still-paused queue is a loud error: this
    /// thread is the only thing that could ever run the submission, so
    /// waiting would deadlock permanently.
    #[cfg(not(feature = "xla-shared-client"))]
    fn drive_inline(&self) -> Result<()> {
        loop {
            if matches!(&*lock(&self.handle.state), HandleState::Finished(_)) {
                return Ok(());
            }
            let (entry, paused) = {
                let mut st = lock(&self.shared.state);
                let entry = take_next(&mut st);
                (entry, st.paused)
            };
            match entry {
                Some(e) => run_entry(&self.shared, e),
                None if paused => anyhow::bail!(
                    "join on a paused queue: this build has no worker \
                     threads (xla-shared-client off), so nothing can run \
                     submission #{} until RunQueue::release() is called",
                    self.handle.seq
                ),
                None => return Ok(()),
            }
        }
    }
}

/// Join every handle (in the given order) and return the results, or —
/// if any job failed — the error of the **lowest submission index**,
/// matching `WorkerPool::scatter`'s deterministic error contract.
/// Cancelled submissions are normal results, not errors.
pub fn join_all<R: 'static>(handles: Vec<RunHandle<R>>) -> Result<Vec<RunResult<R>>> {
    let mut out = Vec::with_capacity(handles.len());
    let mut first_err: Option<(u64, anyhow::Error)> = None;
    for h in handles {
        let seq = h.seq();
        match h.join() {
            Ok(r) => out.push(r),
            Err(e) => {
                let lower = match &first_err {
                    None => true,
                    Some((s, _)) => seq < *s,
                };
                if lower {
                    first_err = Some((seq, e));
                }
            }
        }
    }
    match first_err {
        Some((_, e)) => Err(e),
        None => Ok(out),
    }
}

#[cfg(test)]
mod tests {
    //! Queue mechanics only — plain-closure jobs, no xla, no artifacts.
    //! These run (and must hold) in both the gated build (real worker
    //! threads) and the default build (inline drain at `join`); training
    //! runs through the queue live in `rust/tests/sched_queue.rs`.
    use super::*;

    #[test]
    fn priority_pops_highest_first_fifo_within_class() {
        // Cold backlog: everything submitted while the queue is paused,
        // then released — execution order is pure scheduling policy.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for (name, prio) in [("a0", 0), ("b1", 1), ("c0", 0), ("d1", 1), ("e2", 2)] {
            let order = Arc::clone(&order);
            handles.push(q.submit("t", prio, move |_| {
                lock(&order).push(name);
                Ok(1usize)
            }));
        }
        assert_eq!(q.pending(), 5);
        assert!(handles.iter().all(|h| h.poll() == RunPoll::Queued));
        q.release();
        let results = join_all(handles).unwrap();
        assert_eq!(results.len(), 5);
        assert_eq!(
            *lock(&order),
            vec!["e2", "b1", "d1", "a0", "c0"],
            "highest class first, FIFO within a class"
        );
        assert_eq!(q.pending(), 0);
        let t = q.tenant("t");
        assert_eq!(t.submitted, 5);
        assert_eq!(t.completed, 5);
    }

    #[test]
    fn exactly_once_execution_and_submission_ordered_results() {
        // Hammer the queue with many shuffled-priority submissions:
        // every job runs exactly once and every handle joins to its own
        // job's result, regardless of execution order.
        let n = 200usize;
        let q: RunQueue<usize> = RunQueue::new(4);
        let counts: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(vec![0; n]));
        let mut handles = Vec::new();
        for i in 0..n {
            let counts = Arc::clone(&counts);
            handles.push(q.submit("t", (i % 5) as i32, move |_| {
                lock(&counts)[i] += 1;
                Ok(i * 3)
            }));
        }
        let results = join_all(handles).unwrap();
        let vals: Vec<usize> = results.into_iter().map(|r| r.done().unwrap()).collect();
        assert_eq!(vals, (0..n).map(|i| i * 3).collect::<Vec<_>>());
        assert!(lock(&counts).iter().all(|&c| c == 1), "every job exactly once");
    }

    #[cfg(feature = "xla-shared-client")]
    #[test]
    fn concurrent_submitters_see_exactly_once_and_their_own_results() {
        // Many submitter threads share one queue; each joins only its own
        // handles. No lost wakeups, no cross-talk, exact tenant counts.
        let q = Arc::new(RunQueue::<u64>::new(3));
        let total = Arc::new(std::sync::atomic::AtomicU64::new(0));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let q = Arc::clone(&q);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    let tenant = format!("t{t}");
                    let mut handles = Vec::new();
                    for i in 0..50u64 {
                        let total = Arc::clone(&total);
                        handles.push(q.submit(&tenant, (i % 3) as i32, move |_| {
                            total.fetch_add(1, Ordering::Relaxed);
                            Ok(t * 1000 + i)
                        }));
                    }
                    let rs = join_all(handles).unwrap();
                    for (i, r) in rs.into_iter().enumerate() {
                        assert_eq!(r.done().unwrap(), t * 1000 + i as u64);
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 200);
        let tenants = q.tenants();
        assert_eq!(tenants.len(), 4);
        for stats in tenants.values() {
            assert_eq!(stats.submitted, 50);
            assert_eq!(stats.completed, 50);
        }
    }

    #[test]
    fn panicking_job_fails_its_handle_instead_of_hanging_joiners() {
        // An unwinding job must not kill a worker with the handle stuck
        // at Running — joins would block forever. The unwind is caught
        // and surfaced as the submission's error; the queue keeps
        // serving later submissions.
        let q: RunQueue<usize> = RunQueue::new(1);
        let bad = q.submit("t", 1, |_| -> Result<usize> { panic!("boom in job") });
        let good = q.submit("t", 0, |_| Ok(5usize));
        let err = bad.join().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("boom in job"), "{msg}");
        assert_eq!(good.join().unwrap().done(), Some(5), "queue survives the panic");
        assert_eq!(q.tenant("t").failed, 1);
    }

    #[test]
    fn join_all_reports_the_lowest_submission_index_error() {
        // Parity with WorkerPool::scatter's deterministic error contract.
        let q: RunQueue<usize> = RunQueue::new(2);
        let mut handles = Vec::new();
        for i in 0..16usize {
            handles.push(q.submit("t", 0, move |_| {
                if i == 3 || i == 11 {
                    anyhow::bail!("boom at {i}");
                }
                Ok(i)
            }));
        }
        let err = join_all(handles).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("queued run #3"), "{msg}");
        assert!(msg.contains("boom at 3"), "{msg}");
        let t = q.tenant("t");
        assert_eq!(t.failed, 2);
        assert_eq!(t.completed, 14);
    }

    #[test]
    fn cancel_before_start_never_runs_the_job() {
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let ran = Arc::new(Mutex::new(false));
        let h = {
            let ran = Arc::clone(&ran);
            q.submit("t", 0, move |_| {
                *lock(&ran) = true;
                Ok(1)
            })
        };
        let keeper = q.submit("t", 0, |_| Ok(2usize));
        h.cancel();
        assert_eq!(h.poll(), RunPoll::Cancelled);
        q.release();
        match h.join().unwrap() {
            RunResult::Cancelled(None) => {}
            _ => panic!("cancel-before-start must report Cancelled(None)"),
        }
        assert_eq!(keeper.join().unwrap().done(), Some(2));
        assert!(!*lock(&ran), "cancelled submission must never execute");
        let t = q.tenant("t");
        assert_eq!(t.submitted, 2);
        assert_eq!(t.cancelled, 1);
        assert_eq!(t.completed, 1);
    }

    #[test]
    fn cooperative_cancel_reports_cancelled_with_partial_output() {
        // A job that observes its cancel flag mid-way and stops at its
        // next boundary comes back Cancelled *with* the partial output —
        // the queue-level contract Trainer::run's cooperative flag rides.
        let q: RunQueue<&'static str> = RunQueue::new(1);
        let h = q.submit("t", 0, |token| {
            token.flag().store(true, Ordering::SeqCst);
            assert!(token.is_cancelled());
            Ok("partial")
        });
        match h.join().unwrap() {
            RunResult::Cancelled(Some("partial")) => {}
            _ => panic!("flagged job must come back Cancelled with output"),
        }
        assert_eq!(q.tenant("t").cancelled, 1);
    }

    #[cfg(not(feature = "xla-shared-client"))]
    #[test]
    fn joining_a_paused_queue_without_workers_errors_instead_of_hanging() {
        // Inline-drain build: the joining thread is the only thing that
        // could ever run the submission, so a paused queue must fail the
        // join loudly rather than deadlock on a condvar nobody signals.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let h = q.submit("t", 0, |_| Ok(1));
        let err = h.join().unwrap_err();
        assert!(format!("{err:#}").contains("paused"), "{err:#}");
    }

    #[test]
    fn dropping_the_queue_cancels_queued_submissions() {
        // Joiners must never hang on work nobody will run.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let h = q.submit("t", 0, |_| Ok(7));
        drop(q);
        match h.join().unwrap() {
            RunResult::Cancelled(None) => {}
            _ => panic!("queue drop must cancel still-queued submissions"),
        }
    }

    #[cfg(feature = "xla-shared-client")]
    #[test]
    fn join_never_misses_a_workers_completion() {
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = q.submit("t", 0, |_| {
            std::thread::sleep(std::time::Duration::from_millis(30));
            Ok(9)
        });
        assert!(matches!(h.poll(), RunPoll::Queued | RunPoll::Running | RunPoll::Done));
        assert_eq!(h.join().unwrap().done(), Some(9));
    }

    #[test]
    fn workers_reports_the_builds_effective_width() {
        let q: RunQueue<usize> = RunQueue::new(3);
        let expected = if crate::sched::threads_enabled() { 3 } else { 0 };
        assert_eq!(q.workers(), expected);
    }
}
