//! Cross-host grid sharding: a versioned manifest-of-runs, deterministic
//! shard assignment, content-hash lockfiles, and a streaming report merge.
//!
//! The paper's grid (models × tasks × FF on/off) is embarrassingly
//! parallel *across hosts*, not just across threads: each cell is an
//! independent training run. This module turns one grid into N shards that
//! different containers can execute independently and then fold back into
//! the canonical single-host report **byte-for-byte**:
//!
//! 1. `experiment --emit-manifest F` writes a [`GridManifest`] (one
//!    [`CellSpec`] per run) plus a [`GridLock`] pinning every artifact's
//!    canonical content hash (`docs/artifact-store.md`).
//! 2. `experiment --manifest F --shard i/N` runs the round-robin slice
//!    `index % N == i-1` ([`GridManifest::shard_cells`] — the union over
//!    shards is exactly the unsharded grid) and writes
//!    `reports/shard-i-of-N/grid-<name>.json`.
//! 3. `experiment --merge dir...` splices the per-shard rows back together
//!    ([`merge_shards`]) via the zero-alloc streaming reader
//!    (`crate::util::json_reader`): row bytes are copied verbatim, never
//!    deserialized into an owned tree, so the merged report is
//!    byte-identical to what one host running the whole grid writes.
//!
//! Byte-identity holds because (a) every report — unsharded, per-shard,
//! merged — goes through the same hand-rolled [`write_grid_report`], (b)
//! rows contain only deterministic fields (losses, step/FLOP/transfer
//! counts; never wall-clock), and (c) runs themselves are bit-identical at
//! any `--jobs` level (module docs of [`crate::sched`]).

use std::collections::{BTreeMap, BTreeSet};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{presets, TrainConfig};
use crate::model::tensor::Tensor;
use crate::runtime::Runtime;
use crate::sched::{ArtifactCache, RunOutput, RunSpec, WorkerPool};
use crate::store::{ArtifactStore, StoreSnapshot};
use crate::train::pretrain::ensure_pretrained_via;
use crate::train::trainer::StopRule;
use crate::util::json::Json;
use crate::util::json_reader::{scan, Event};

/// Version of both the grid manifest and the grid report headers. Readers
/// accept anything ≤ this and reject newer files loudly (no silent
/// misinterpretation across heterogenous hosts).
pub const GRID_FORMAT_VERSION: usize = 1;

/// One grid cell: a fully-specified training run plus its stable position
/// in the grid. `index` is the sharding and merge key — it must be unique
/// and dense (`0..cells.len()`) within a manifest.
#[derive(Debug, Clone)]
pub struct CellSpec {
    pub index: usize,
    pub label: String,
    pub cfg: TrainConfig,
}

impl CellSpec {
    /// Flat JSON row. Only the fields that vary across a grid are
    /// serialized; everything else re-derives from the task presets on
    /// load, so manifests stay small and old manifests keep working when
    /// `TrainConfig` grows fields.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("artifact", self.cfg.artifact.as_str())
            .set("ff", self.cfg.ff.enabled)
            .set("global_batch", self.cfg.global_batch)
            .set("index", self.index)
            .set("label", self.label.as_str())
            .set("lr", self.cfg.lr as f64)
            .set("seed", self.cfg.seed as i64)
            .set("steps", self.cfg.max_steps)
            .set("task", self.cfg.task.as_str())
            .set("test_examples", self.cfg.test_examples)
            .set("train_examples", self.cfg.train_examples)
    }

    /// Parse one cell, defaulting every absent knob from the task preset
    /// ([`presets::train_config`]) and ignoring unknown fields.
    pub fn from_json(j: &Json) -> Result<CellSpec> {
        let artifact =
            j.get("artifact").as_str().ok_or_else(|| anyhow!("cell missing 'artifact'"))?;
        let task = j.get("task").as_str().ok_or_else(|| anyhow!("cell missing 'task'"))?;
        let index = j.get("index").as_usize().ok_or_else(|| anyhow!("cell missing 'index'"))?;
        let mut cfg = presets::train_config(artifact, task, 1)?;
        if let Some(v) = j.get("lr").as_f64() {
            cfg.lr = v as f32;
        }
        if let Some(v) = j.get("global_batch").as_usize() {
            cfg.global_batch = v;
        }
        if let Some(v) = j.get("steps").as_usize() {
            cfg.max_steps = v;
        }
        if let Some(v) = j.get("seed").as_i64() {
            cfg.seed = v as u64;
        }
        if let Some(v) = j.get("train_examples").as_usize() {
            cfg.train_examples = v;
        }
        if let Some(v) = j.get("test_examples").as_usize() {
            cfg.test_examples = v;
        }
        if let Some(v) = j.get("ff").as_bool() {
            cfg.ff.enabled = v;
        }
        let label =
            j.get("label").as_str().map(str::to_string).unwrap_or_else(|| format!("cell{index}"));
        Ok(CellSpec { index, label, cfg })
    }
}

/// A versioned manifest-of-runs: the unit every shard agrees on. Emit once
/// (`--emit-manifest`), copy to every host, run slices against it.
#[derive(Debug, Clone)]
pub struct GridManifest {
    pub name: String,
    pub cells: Vec<CellSpec>,
}

impl GridManifest {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("cells", Json::Arr(self.cells.iter().map(CellSpec::to_json).collect()))
            .set("format_version", GRID_FORMAT_VERSION)
            .set("name", self.name.as_str())
    }

    /// Parse a manifest: unknown fields are ignored (forward-tolerant),
    /// a `format_version` newer than this build is rejected loudly.
    pub fn parse(text: &str) -> Result<GridManifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("grid manifest: {e}"))?;
        let version = match j.get("format_version") {
            Json::Null => 1, // pre-versioned files default to v1
            v => v.as_usize().ok_or_else(|| anyhow!("grid manifest: bad format_version"))?,
        };
        if version > GRID_FORMAT_VERSION {
            bail!(
                "grid manifest is format_version {version}, this build reads \
                 ≤ {GRID_FORMAT_VERSION} — update the binary or re-emit the manifest"
            );
        }
        let name = j.get("name").as_str().unwrap_or("grid").to_string();
        let cells = j
            .get("cells")
            .as_arr()
            .ok_or_else(|| anyhow!("grid manifest: missing 'cells' array"))?
            .iter()
            .enumerate()
            .map(|(i, c)| CellSpec::from_json(c).with_context(|| format!("cell #{i}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(GridManifest { name, cells })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, format!("{}\n", self.to_json().to_string_pretty()).as_bytes())
    }

    pub fn load(path: &Path) -> Result<GridManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading grid manifest {}", path.display()))?;
        GridManifest::parse(&text).with_context(|| path.display().to_string())
    }

    /// Deterministic round-robin shard assignment: shard `i` of `n`
    /// (1-based) owns every cell with `index % n == i - 1`. The union over
    /// all shards is exactly the full grid and shards are pairwise
    /// disjoint, at any `n` (asserted by tests below and the CI selftest).
    pub fn shard_cells(&self, shard: Option<(usize, usize)>) -> Vec<&CellSpec> {
        match shard {
            None => self.cells.iter().collect(),
            Some((i, n)) => self.cells.iter().filter(|c| c.index % n == i - 1).collect(),
        }
    }

    /// Every distinct artifact key the grid touches (lockfile domain).
    pub fn artifact_keys(&self) -> BTreeSet<String> {
        self.cells.iter().map(|c| c.cfg.artifact.clone()).collect()
    }
}

/// Parse a `--shard i/N` argument (1-based, `1 ≤ i ≤ N`).
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let (i, n) = s.split_once('/').ok_or_else(|| anyhow!("--shard wants i/N, e.g. 1/2"))?;
    let i: usize = i.trim().parse().with_context(|| format!("--shard {s}"))?;
    let n: usize = n.trim().parse().with_context(|| format!("--shard {s}"))?;
    if n == 0 || i == 0 || i > n {
        bail!("--shard {s}: want 1 ≤ i ≤ N");
    }
    Ok((i, n))
}

/// Lockfile pinning every artifact the grid uses to its canonical content
/// hash (`docs/artifact-store.md` §Lockfile). Every shard verifies its
/// local (or store-materialized) artifacts against these pins and fails
/// fast on any mismatch — a grid never mixes rebuilt programs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GridLock {
    /// Artifact key → 64-hex canonical content hash.
    pub artifacts: BTreeMap<String, String>,
}

impl GridLock {
    /// Hash every artifact the manifest references out of the local
    /// artifacts root (verifying each dir's recorded stamp on the way).
    pub fn emit(manifest: &GridManifest, artifacts_root: &Path) -> Result<GridLock> {
        let mut artifacts = BTreeMap::new();
        for key in manifest.artifact_keys() {
            let dir = artifacts_root.join(&key);
            let hash = crate::store::verify_local_artifact(&dir, &key, None)
                .with_context(|| format!("locking artifact '{key}'"))?;
            artifacts.insert(key, hash);
        }
        Ok(GridLock { artifacts })
    }

    pub fn to_json(&self) -> Json {
        let pins = self
            .artifacts
            .iter()
            .fold(Json::obj(), |j, (k, v)| j.set(k, v.as_str()));
        Json::obj().set("artifacts", pins).set("format_version", GRID_FORMAT_VERSION)
    }

    pub fn parse(text: &str) -> Result<GridLock> {
        let j = Json::parse(text).map_err(|e| anyhow!("grid lockfile: {e}"))?;
        let pins = j
            .get("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow!("grid lockfile: missing 'artifacts' object"))?;
        let mut artifacts = BTreeMap::new();
        for (k, v) in pins {
            let hash =
                v.as_str().ok_or_else(|| anyhow!("grid lockfile: pin for '{k}' is not a string"))?;
            artifacts.insert(k.clone(), hash.to_string());
        }
        Ok(GridLock { artifacts })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, format!("{}\n", self.to_json().to_string_pretty()).as_bytes())
    }

    pub fn load(path: &Path) -> Result<GridLock> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading grid lockfile {}", path.display()))?;
        GridLock::parse(&text).with_context(|| path.display().to_string())
    }

    /// Conventional lockfile location: `<manifest>.lock` next to the
    /// manifest itself.
    pub fn lock_path(manifest_path: &Path) -> PathBuf {
        let mut os = manifest_path.as_os_str().to_os_string();
        os.push(".lock");
        PathBuf::from(os)
    }

    /// Install every pin into an [`ArtifactCache`]: first load of each key
    /// verifies the local/materialized dir hashes to exactly the pin.
    pub fn apply(&self, cache: &ArtifactCache) {
        for (key, hash) in &self.artifacts {
            cache.pin(key, hash);
        }
    }
}

/// What one `run_grid` call produced.
pub struct GridRunOutcome {
    pub report_path: PathBuf,
    pub cells_run: usize,
    /// Store-traffic window over the whole grid slice (artifact loads, W0
    /// publishes/fetches), `None` without a store. The CI shard selftest
    /// asserts a warm second shard shows zero misses/builds/ingests here.
    pub store: Option<StoreSnapshot>,
}

/// Canonical report file name for a grid (same in shard dirs and merged).
pub fn report_file_name(name: &str) -> String {
    format!("grid-{name}.json")
}

/// Directory a shard's report lands in: `reports/shard-<i>-of-<n>/`.
pub fn shard_dir(reports_dir: &Path, shard: (usize, usize)) -> PathBuf {
    reports_dir.join(format!("shard-{}-of-{}", shard.0, shard.1))
}

/// The model a grid artifact key belongs to (keys are
/// `<model>_<mode>[...]` and model names never contain `_`).
fn model_of(artifact: &str) -> &str {
    artifact.split('_').next().unwrap_or(artifact)
}

/// Execute one slice of a grid manifest and write its report.
///
/// With `store`, artifact and W0 resolution go through the
/// content-addressed store ([`ArtifactCache::with_store`],
/// [`ensure_pretrained_via`]): local builds are published, local misses
/// materialize from the store — a warm second host runs the grid with
/// zero compiles and zero W0 rebuilds. With `lock`, every artifact is
/// pinned to its locked content hash and mismatches fail fast.
#[allow(clippy::too_many_arguments)]
pub fn run_grid(
    rt: &Arc<Runtime>,
    artifacts_root: &Path,
    store: Option<Arc<ArtifactStore>>,
    manifest: &GridManifest,
    lock: Option<&GridLock>,
    shard: Option<(usize, usize)>,
    reports_dir: &Path,
    jobs: usize,
) -> Result<GridRunOutcome> {
    let cache = match store {
        Some(s) => ArtifactCache::with_store(artifacts_root.to_path_buf(), s),
        None => ArtifactCache::new(artifacts_root.to_path_buf()),
    };
    if let Some(lock) = lock {
        lock.apply(&cache);
    }
    let store0 = cache.store().map(|s| s.stats.snapshot());

    let cells = manifest.shard_cells(shard);
    let slice = match shard {
        Some((i, n)) => format!("shard {i}/{n}"),
        None => "unsharded".to_string(),
    };
    crate::info!(
        "grid '{}': {} → {} of {} cells",
        manifest.name,
        slice,
        cells.len(),
        manifest.cells.len()
    );

    // One shared W0 per distinct model in this slice (the paper's runs all
    // start from the same pretrained point; see train::pretrain).
    let mut bases: BTreeMap<String, Arc<BTreeMap<String, Tensor>>> = BTreeMap::new();
    for cell in &cells {
        let model = model_of(&cell.cfg.artifact).to_string();
        if !bases.contains_key(&model) {
            let w0 = ensure_pretrained_via(
                rt,
                artifacts_root,
                &model,
                None,
                cache.store().map(|s| s.as_ref()),
            )?;
            bases.insert(model, Arc::new(w0));
        }
    }

    let specs: Vec<RunSpec> = cells
        .iter()
        .map(|c| RunSpec {
            label: c.label.clone(),
            cfg: c.cfg.clone(),
            stop: StopRule::MaxSteps(c.cfg.max_steps),
            base: Some(Arc::clone(&bases[model_of(&c.cfg.artifact)])),
            drain_interval: None,
        })
        .collect();
    let run = WorkerPool::new(jobs).run_all(rt, &cache, specs)?;

    let rows: Vec<String> =
        cells.iter().zip(run.outputs.iter()).map(|(c, o)| row_json(c, o)).collect();
    let (dir, shard_header) = match shard {
        Some((i, n)) => (shard_dir(reports_dir, (i, n)), Some((i, n, manifest.cells.len()))),
        None => (reports_dir.to_path_buf(), None),
    };
    let report_path = dir.join(report_file_name(&manifest.name));
    write_grid_report(&report_path, &manifest.name, shard_header, &rows)?;

    let store_delta = match (store0, cache.store()) {
        (Some(before), Some(s)) => {
            let delta = s.stats.snapshot().since(&before);
            crate::info!("grid '{}' store traffic: {}", manifest.name, delta.report());
            Some(delta)
        }
        _ => None,
    };
    Ok(GridRunOutcome { report_path, cells_run: cells.len(), store: store_delta })
}

/// One report row: **deterministic fields only** (no wall-clock), compact
/// single-line JSON with sorted keys — the byte-identity unit the shard
/// merge splices verbatim.
fn row_json(cell: &CellSpec, out: &RunOutput) -> String {
    let t = &out.summary.transfers;
    Json::obj()
        .set("adam_steps", out.summary.adam_steps)
        // null, not the invalid `NaN` token, when the run never ran its
        // final eval (a parked summary) — see Json::num_or_null.
        .set("final_loss", Json::num_or_null(out.summary.final_test_loss as f64))
        .set("flops", out.summary.flops.total() as i64)
        .set("index", cell.index)
        .set("label", cell.label.as_str())
        .set("sim_steps", out.summary.sim_steps)
        .set(
            "transfer_bytes",
            (t.uploaded_bytes + t.downloaded_bytes + t.donated_bytes) as i64,
        )
        .to_string()
}

/// The one writer every grid report goes through — unsharded, per-shard,
/// and merged reports all serialize here, which is what makes "merge ==
/// unsharded" a byte-for-byte identity rather than a semantic one. Rows
/// are pre-serialized single-line JSON strings, spliced in as-is.
pub fn write_grid_report(
    path: &Path,
    name: &str,
    shard: Option<(usize, usize, usize)>,
    rows: &[String],
) -> Result<()> {
    let mut out = String::new();
    out.push_str("{\n \"cells\": [");
    for (i, row) in rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("\n  ");
        out.push_str(row);
    }
    if !rows.is_empty() {
        out.push_str("\n ");
    }
    out.push_str("],\n \"format_version\": ");
    out.push_str(&GRID_FORMAT_VERSION.to_string());
    out.push_str(",\n \"name\": ");
    out.push_str(&Json::Str(name.to_string()).to_string());
    if let Some((i, n, total)) = shard {
        out.push_str(&format!(
            ",\n \"shard\": {{\"cells_total\":{total},\"index\":{i},\"of\":{n}}}"
        ));
    }
    out.push_str("\n}\n");
    atomic_write(path, out.as_bytes())
}

/// What the streaming pass recovers from one report file: identity, the
/// shard header, and each cell row as an exact byte span into the source.
struct ReportScan {
    name: String,
    /// `(shard index, of, cells_total)` — `None` for an unsharded report.
    shard: Option<(usize, usize, usize)>,
    /// `(cell index, byte span of the row object)` in file order.
    rows: Vec<(usize, Range<usize>)>,
}

/// Single streaming pass over a grid report using the callback lexer
/// (`crate::util::json_reader`): no owned value tree, no per-row
/// allocation — just depth tracking and span capture.
fn scan_report(src: &str, what: &str) -> Result<ReportScan> {
    let mut depth = 0usize;
    let mut top_key: Option<&str> = None;
    let mut in_cells = false;
    let mut row_start: Option<usize> = None;
    let mut row_key: Option<&str> = None;
    let mut row_index: Option<usize> = None;
    let mut rows: Vec<(usize, Range<usize>)> = Vec::new();
    let mut in_shard = false;
    let mut shard_key: Option<&str> = None;
    let mut shard_vals: BTreeMap<&str, usize> = BTreeMap::new();
    let mut name: Option<String> = None;
    let mut version: Option<usize> = None;
    let mut bad: Option<String> = None;

    scan(src, &mut |off, ev| match ev {
        Event::Key(k) => {
            if depth == 1 {
                top_key = Some(k);
            } else if depth == 3 && in_cells {
                row_key = Some(k);
            } else if depth == 2 && in_shard {
                shard_key = Some(k);
            }
        }
        Event::ObjectStart => {
            if depth == 2 && in_cells {
                row_start = Some(off);
                row_index = None;
            } else if depth == 1 && top_key == Some("shard") {
                in_shard = true;
            }
            depth += 1;
        }
        Event::ArrayStart => {
            if depth == 1 && top_key == Some("cells") {
                in_cells = true;
            }
            depth += 1;
        }
        Event::ObjectEnd => {
            depth -= 1;
            if depth == 2 && in_cells {
                match (row_start.take(), row_index.take()) {
                    (Some(start), Some(idx)) => rows.push((idx, start..off + 1)),
                    _ => {
                        bad.get_or_insert_with(|| "cell row has no 'index'".to_string());
                    }
                }
            } else if depth == 1 && in_shard {
                in_shard = false;
            }
        }
        Event::ArrayEnd => {
            depth -= 1;
            if depth == 1 && in_cells {
                in_cells = false;
            }
        }
        Event::Num(s) => {
            if depth == 3 && in_cells && row_key == Some("index") {
                match s.parse::<usize>() {
                    Ok(v) => row_index = Some(v),
                    Err(_) => {
                        bad.get_or_insert_with(|| format!("bad cell index '{s}'"));
                    }
                }
            } else if depth == 2 && in_shard {
                if let (Some(k), Ok(v)) = (shard_key, s.parse::<usize>()) {
                    shard_vals.insert(k, v);
                }
            } else if depth == 1 && top_key == Some("format_version") {
                version = s.parse::<usize>().ok();
            }
        }
        Event::Str(s) => {
            if depth == 1 && top_key == Some("name") {
                // Raw (undecoded) span: re-wrap the original quotes and
                // decode through the tree parser — one tiny string, not
                // the whole file.
                name = Json::parse(&format!("\"{s}\""))
                    .ok()
                    .and_then(|j| j.as_str().map(str::to_string));
            }
        }
        _ => {}
    })
    .map_err(|e| anyhow!("{what}: {e}"))?;

    if let Some(msg) = bad {
        bail!("{what}: {msg}");
    }
    let name = name.ok_or_else(|| anyhow!("{what}: report has no 'name'"))?;
    let version = version.ok_or_else(|| anyhow!("{what}: report has no 'format_version'"))?;
    if version > GRID_FORMAT_VERSION {
        bail!(
            "{what}: report is format_version {version}, this build reads \
             ≤ {GRID_FORMAT_VERSION}"
        );
    }
    let shard = match (
        shard_vals.get("index").copied(),
        shard_vals.get("of").copied(),
        shard_vals.get("cells_total").copied(),
    ) {
        (Some(i), Some(n), Some(t)) => Some((i, n, t)),
        (None, None, None) => None,
        _ => bail!("{what}: incomplete 'shard' header"),
    };
    Ok(ReportScan { name, shard, rows })
}

/// The single `grid-*.json` report inside one shard directory.
pub fn shard_report_file(dir: &Path) -> Result<PathBuf> {
    let mut found = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("reading shard dir {}", dir.display()))?
    {
        let p = entry?.path();
        let is_report = p
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.starts_with("grid-") && n.ends_with(".json"))
            .unwrap_or(false);
        if is_report {
            found.push(p);
        }
    }
    found.sort();
    match found.len() {
        1 => Ok(found.remove(0)),
        0 => bail!("{}: no grid-*.json shard report", dir.display()),
        _ => bail!("{}: multiple grid reports: {found:?}", dir.display()),
    }
}

/// Fold per-shard reports back into the canonical single-host report.
///
/// Validates that every file belongs to the same grid (name + shard
/// header agreement), that no shard or cell index repeats, and that the
/// union covers exactly `0..cells_total` — then reassembles the rows in
/// index order through [`write_grid_report`]. Row bytes are spliced
/// verbatim from each source file (spans from the streaming reader), so
/// the output is byte-identical to an unsharded run's report.
pub fn merge_shards(files: &[PathBuf], out_dir: &Path) -> Result<PathBuf> {
    if files.is_empty() {
        bail!("merge: no shard reports given");
    }
    let mut name: Option<String> = None;
    let mut header: Option<(usize, usize)> = None; // (of, cells_total)
    let mut seen_shards: BTreeSet<usize> = BTreeSet::new();
    let mut rows: BTreeMap<usize, String> = BTreeMap::new();
    for path in files {
        let what = path.display().to_string();
        let src =
            std::fs::read_to_string(path).with_context(|| format!("reading {what}"))?;
        let rep = scan_report(&src, &what)?;
        let (i, n, total) = rep
            .shard
            .ok_or_else(|| anyhow!("{what}: not a shard report (no 'shard' header)"))?;
        match &name {
            None => name = Some(rep.name.clone()),
            Some(prev) if *prev != rep.name => {
                bail!("{what}: grid name '{}' does not match '{prev}'", rep.name)
            }
            _ => {}
        }
        match header {
            None => header = Some((n, total)),
            Some((pn, pt)) if (pn, pt) != (n, total) => bail!(
                "{what}: shard header says {n} shards / {total} cells, \
                 earlier files said {pn} / {pt}"
            ),
            _ => {}
        }
        if !seen_shards.insert(i) {
            bail!("{what}: shard {i} appears twice in the merge set");
        }
        for (idx, span) in rep.rows {
            let row = src[span].to_string();
            if rows.insert(idx, row).is_some() {
                bail!("{what}: duplicate cell index {idx}");
            }
        }
    }
    let name = name.expect("files is non-empty");
    let (_, total) = header.expect("files is non-empty");
    for i in 0..total {
        if !rows.contains_key(&i) {
            bail!("merge: cell index {i} is missing ({} of {total} rows present)", rows.len());
        }
    }
    if rows.len() != total {
        bail!("merge: {} rows but the grid has {total} cells", rows.len());
    }
    let ordered: Vec<String> = rows.into_values().collect();
    let out_path = out_dir.join(report_file_name(&name));
    write_grid_report(&out_path, &name, None, &ordered)?;
    Ok(out_path)
}

/// Temp-then-rename write (same contract as the store's object writes):
/// a crashed process leaves a stray `.tmp.<pid>`, never a torn report.
fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ff-shard-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn demo_manifest(n: usize) -> GridManifest {
        let cells = (0..n)
            .map(|i| {
                let task = ["medical", "instruct", "chat"][i % 3];
                let mut cfg =
                    presets::train_config("ff-tiny_lora_r8", task, 1).unwrap();
                cfg.max_steps = 3 + i;
                cfg.ff.enabled = i % 2 == 0;
                CellSpec { index: i, label: format!("c{i}/{task}"), cfg }
            })
            .collect();
        GridManifest { name: "demo".into(), cells }
    }

    fn demo_rows(n: usize) -> Vec<String> {
        (0..n)
            .map(|i| {
                Json::obj()
                    .set("adam_steps", 3 + i)
                    .set("final_loss", 0.5 + i as f64 * 0.25)
                    .set("index", i)
                    .set("label", format!("c{i}"))
                    .to_string()
            })
            .collect()
    }

    #[test]
    fn manifest_round_trips() {
        let m = demo_manifest(6);
        let text = m.to_json().to_string_pretty();
        let back = GridManifest::parse(&text).unwrap();
        assert_eq!(back.name, m.name);
        assert_eq!(back.cells.len(), m.cells.len());
        for (a, b) in m.cells.iter().zip(back.cells.iter()) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.label, b.label);
            assert_eq!(a.cfg.artifact, b.cfg.artifact);
            assert_eq!(a.cfg.task, b.cfg.task);
            assert_eq!(a.cfg.max_steps, b.cfg.max_steps);
            assert_eq!(a.cfg.seed, b.cfg.seed);
            assert_eq!(a.cfg.lr.to_bits(), b.cfg.lr.to_bits());
            assert_eq!(a.cfg.global_batch, b.cfg.global_batch);
            assert_eq!(a.cfg.train_examples, b.cfg.train_examples);
            assert_eq!(a.cfg.test_examples, b.cfg.test_examples);
            assert_eq!(a.cfg.ff.enabled, b.cfg.ff.enabled);
        }
    }

    #[test]
    fn manifest_tolerates_unknown_fields_and_defaults_absent_ones() {
        // A future emitter added fields; a minimal cell omits every knob.
        let text = r#"{
            "format_version": 1,
            "name": "fwd",
            "future_top_level_knob": {"x": 1},
            "cells": [
                {"artifact": "ff-tiny_lora_r8", "task": "medical",
                 "index": 0, "some_future_field": [1, 2, 3]}
            ]
        }"#;
        let m = GridManifest::parse(text).unwrap();
        assert_eq!(m.cells.len(), 1);
        let want = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
        let got = &m.cells[0].cfg;
        assert_eq!(got.max_steps, want.max_steps);
        assert_eq!(got.lr.to_bits(), want.lr.to_bits());
        assert_eq!(got.global_batch, want.global_batch);
        assert_eq!(got.seed, want.seed);
        assert!(got.ff.enabled, "ff defaults on");
        assert_eq!(m.cells[0].label, "cell0", "label defaults from the index");
    }

    #[test]
    fn manifest_rejects_newer_format_versions() {
        let text = r#"{"format_version": 2, "name": "x", "cells": []}"#;
        let err = GridManifest::parse(text).unwrap_err().to_string();
        assert!(err.contains("format_version 2"), "{err}");
        // ...and a missing version defaults to 1 (pre-versioned files).
        let ok = GridManifest::parse(r#"{"name": "x", "cells": []}"#).unwrap();
        assert!(ok.cells.is_empty());
    }

    #[test]
    fn manifest_requires_cell_identity_fields() {
        let missing_artifact =
            r#"{"name": "x", "cells": [{"task": "medical", "index": 0}]}"#;
        assert!(GridManifest::parse(missing_artifact).is_err());
        let missing_index =
            r#"{"name": "x", "cells": [{"artifact": "ff-tiny_lora_r8", "task": "medical"}]}"#;
        assert!(GridManifest::parse(missing_index).is_err());
    }

    #[test]
    fn shard_parse_accepts_only_sane_slices() {
        assert_eq!(parse_shard("1/2").unwrap(), (1, 2));
        assert_eq!(parse_shard("4/4").unwrap(), (4, 4));
        for bad in ["0/2", "3/2", "1/0", "x/2", "1", "1/2/3"] {
            assert!(parse_shard(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn round_robin_union_is_the_whole_grid_and_shards_are_disjoint() {
        let m = demo_manifest(13);
        for n in 1..=5usize {
            let mut seen: BTreeSet<usize> = BTreeSet::new();
            for i in 1..=n {
                for cell in m.shard_cells(Some((i, n))) {
                    assert!(seen.insert(cell.index), "cell {} on two shards (n={n})", cell.index);
                }
            }
            let all: BTreeSet<usize> = (0..13).collect();
            assert_eq!(seen, all, "union over {n} shards misses cells");
        }
        // Unsharded == the full grid in order.
        let all = m.shard_cells(None);
        assert_eq!(all.len(), 13);
        assert!(all.windows(2).all(|w| w[0].index < w[1].index));
    }

    #[test]
    fn lockfile_round_trips_and_sits_next_to_the_manifest() {
        let mut lock = GridLock::default();
        lock.artifacts.insert("ff-tiny_lora_r8".into(), "ab".repeat(32));
        lock.artifacts.insert("ff-small_lora_r8".into(), "cd".repeat(32));
        let back = GridLock::parse(&lock.to_json().to_string_pretty()).unwrap();
        assert_eq!(back, lock);
        assert_eq!(
            GridLock::lock_path(Path::new("/x/grid.json")),
            PathBuf::from("/x/grid.json.lock")
        );
    }

    #[test]
    fn grid_report_writer_emits_valid_json_even_when_empty() {
        let d = tmpdir("writer");
        let p = d.join("grid-demo.json");
        write_grid_report(&p, "demo", None, &[]).unwrap();
        let v = Json::parse(std::fs::read_to_string(&p).unwrap().trim()).unwrap();
        assert_eq!(v.get("name").as_str(), Some("demo"));
        assert_eq!(v.get("cells").as_arr().map(|a| a.len()), Some(0));
        write_grid_report(&p, "demo", Some((2, 3, 9)), &demo_rows(3)).unwrap();
        let v = Json::parse(std::fs::read_to_string(&p).unwrap().trim()).unwrap();
        assert_eq!(v.get("shard").get("index").as_usize(), Some(2));
        assert_eq!(v.get("shard").get("of").as_usize(), Some(3));
        assert_eq!(v.get("shard").get("cells_total").as_usize(), Some(9));
        assert_eq!(v.get("cells").idx(1).get("index").as_usize(), Some(1));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn scan_report_recovers_rows_and_header() {
        let d = tmpdir("scan");
        let rows = demo_rows(4);
        let p = d.join("grid-demo.json");
        write_grid_report(&p, "demo", Some((1, 2, 8)), &rows).unwrap();
        let src = std::fs::read_to_string(&p).unwrap();
        let rep = scan_report(&src, "t").unwrap();
        assert_eq!(rep.name, "demo");
        assert_eq!(rep.shard, Some((1, 2, 8)));
        assert_eq!(rep.rows.len(), 4);
        for (want, (idx, span)) in rows.iter().zip(rep.rows.iter()) {
            // The recovered span is the row's exact bytes — the property
            // the merge's byte-identity rests on.
            assert_eq!(&src[span.clone()], want.as_str());
            assert!(want.contains(&format!("\"index\":{idx}")));
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn merge_reassembles_byte_identically() {
        let d = tmpdir("merge");
        let rows = demo_rows(7);
        // Reference: what one host running the whole grid writes.
        let whole = d.join("whole");
        write_grid_report(&whole.join("grid-demo.json"), "demo", None, &rows).unwrap();
        // Two shards, round-robin split, each through the same writer.
        let mut files = Vec::new();
        for i in 1..=2usize {
            let mine: Vec<String> =
                rows.iter().enumerate().filter(|(k, _)| k % 2 == i - 1).map(|(_, r)| r.clone()).collect();
            let dir = shard_dir(&d, (i, 2));
            write_grid_report(
                &dir.join("grid-demo.json"),
                "demo",
                Some((i, 2, rows.len())),
                &mine,
            )
            .unwrap();
            files.push(shard_report_file(&dir).unwrap());
        }
        let out = d.join("merged");
        let merged = merge_shards(&files, &out).unwrap();
        let a = std::fs::read(whole.join("grid-demo.json")).unwrap();
        let b = std::fs::read(&merged).unwrap();
        assert_eq!(a, b, "merged report must be byte-identical to the unsharded one");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn merge_fails_fast_on_duplicates_gaps_and_mismatches() {
        let d = tmpdir("merge-bad");
        let rows = demo_rows(4);
        let write = |dir: &Path, name: &str, shard, rs: &[String]| {
            write_grid_report(&dir.join(format!("grid-{name}.json")), name, shard, rs).unwrap();
            dir.join(format!("grid-{name}.json"))
        };
        // Duplicate cell: both shards claim row 0.
        let s1 = write(&d.join("a1"), "demo", Some((1, 2, 4)), &rows[0..2]);
        let s2 = write(&d.join("a2"), "demo", Some((2, 2, 4)), &rows[0..2]);
        let err = merge_shards(&[s1, s2], &d.join("out")).unwrap_err().to_string();
        assert!(err.contains("duplicate cell index"), "{err}");
        // Gap: only one shard of two → coverage check trips.
        let s1 = write(&d.join("b1"), "demo", Some((1, 2, 4)), &[rows[0].clone(), rows[2].clone()]);
        let err = merge_shards(&[s1], &d.join("out")).unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
        // Name mismatch across files.
        let s1 = write(&d.join("c1"), "demo", Some((1, 2, 4)), &[rows[0].clone(), rows[2].clone()]);
        let s2 = write(&d.join("c2"), "other", Some((2, 2, 4)), &[rows[1].clone(), rows[3].clone()]);
        let err = merge_shards(&[s1, s2], &d.join("out")).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        // Unsharded input: refuse (nothing to merge).
        let s1 = write(&d.join("d1"), "demo", None, &rows);
        let err = merge_shards(&[s1], &d.join("out")).unwrap_err().to_string();
        assert!(err.contains("no 'shard' header"), "{err}");
        // Same shard twice.
        let s1 = write(&d.join("e1"), "demo", Some((1, 2, 4)), &[rows[0].clone(), rows[2].clone()]);
        let s2 = write(&d.join("e2"), "demo", Some((1, 2, 4)), &[rows[1].clone(), rows[3].clone()]);
        let err = merge_shards(&[s1, s2], &d.join("out")).unwrap_err().to_string();
        assert!(err.contains("appears twice"), "{err}");
        let _ = std::fs::remove_dir_all(&d);
    }
}
