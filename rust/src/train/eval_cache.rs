//! Cached device-side evaluation inputs.
//!
//! The FF line search calls `eval_val()` at every probed τ, and the
//! TargetLoss stop rule evaluates the test set on a fixed cadence — but the
//! underlying batches never change within a run. [`EvalCache`] uploads each
//! batch's tokens/targets/mask device buffers **once** and reuses them
//! across every subsequent probe, turning the hottest upload site of an FF
//! stage into zero-upload steady state (only the loss scalar crosses the
//! host↔device boundary per probe).
//!
//! [`ExampleScratch`] is the companion for per-example QA scoring: the eval
//! program wants a full `[eval_batch, seq_len]` input, so a single example
//! is replicated `b` times with a zero mask on every padding row. The
//! scratch owns those replicated rows and is refilled in place per example
//! instead of reallocating three fresh `Vec`s per call.

use anyhow::Result;

use crate::data::batcher::Batch;
use crate::data::corpus::Example;
use crate::runtime::{upload_f32_opt, upload_i32_opt, Runtime, TransferMeter};

/// One eval batch resident on the device, plus the host-side scalars the
/// loss aggregation needs (mask weight, FLOPs token count).
pub struct EvalChunk {
    pub tokens: xla::PjRtBuffer,
    pub targets: xla::PjRtBuffer,
    pub mask: xla::PjRtBuffer,
    /// Σ mask — the chunk's weight in the token-weighted mean loss.
    pub mask_sum: f32,
    /// b·t positions the forward pass computes over (FLOPs charging).
    pub total_tokens: usize,
}

/// Device-resident copy of a fixed eval split (val or test), built once per
/// trainer and reused across all probes.
pub struct EvalCache {
    chunks: Vec<EvalChunk>,
}

impl EvalCache {
    /// Upload every batch of a split. `batches` is the `(batch, real_rows)`
    /// list produced by `data::batcher::eval_batches`. Batches whose mask
    /// is entirely zero contribute nothing to the weighted mean and are
    /// skipped outright — they never cross the host↔device boundary.
    pub fn build(rt: &Runtime, batches: &[(Batch, usize)]) -> Result<EvalCache> {
        Self::build_metered(rt, None, batches)
    }

    /// [`EvalCache::build`] that additionally tallies the one-time cache
    /// uploads into the owning run's exact [`TransferMeter`].
    pub fn build_metered(
        rt: &Runtime,
        meter: Option<&TransferMeter>,
        batches: &[(Batch, usize)],
    ) -> Result<EvalCache> {
        let mut chunks = Vec::with_capacity(batches.len());
        for (batch, _real) in batches {
            let mask_sum: f32 = batch.mask.iter().sum();
            if mask_sum == 0.0 {
                continue;
            }
            chunks.push(EvalChunk {
                tokens: upload_i32_opt(rt, meter, &batch.tokens, &[batch.b, batch.t])?,
                targets: upload_i32_opt(rt, meter, &batch.targets, &[batch.b, batch.t])?,
                mask: upload_f32_opt(rt, meter, &batch.mask, &[batch.b, batch.t])?,
                mask_sum,
                total_tokens: batch.total_tokens(),
            });
        }
        Ok(EvalCache { chunks })
    }

    pub fn chunks(&self) -> &[EvalChunk] {
        &self.chunks
    }

    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }
}

/// Token-weighted mean-loss aggregation over [`EvalChunk`]s — the reduction
/// every split evaluation performs, factored out so the step engine's eval
/// path and any future consumer share one definition. Weighting by Σ mask
/// per chunk makes the chunked mean equal the in-graph masked mean over the
/// whole split exactly.
#[derive(Debug, Default)]
pub struct LossAccum {
    total: f64,
    weight: f64,
    tokens: usize,
}

impl LossAccum {
    pub fn new() -> LossAccum {
        LossAccum::default()
    }

    /// Fold in one chunk's mean loss.
    pub fn add(&mut self, chunk_loss: f32, chunk: &EvalChunk) {
        self.total += chunk_loss as f64 * chunk.mask_sum as f64;
        self.weight += chunk.mask_sum as f64;
        self.tokens += chunk.total_tokens;
    }

    /// Total b·t positions evaluated (FLOPs charging).
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// The weighted mean loss (0.0 for an empty accumulation).
    pub fn mean(&self) -> f32 {
        (self.total / self.weight.max(1.0)) as f32
    }
}

/// Reusable host staging buffers for single-example eval (QA scoring).
/// Rows 1..b of the mask are zeroed once at construction and never written
/// again; `fill` only rewrites the replicated token/target rows and the
/// first mask row.
pub struct ExampleScratch {
    b: usize,
    t: usize,
    tokens: Vec<i32>,
    targets: Vec<i32>,
    mask: Vec<f32>,
}

impl ExampleScratch {
    pub fn new(b: usize, t: usize) -> ExampleScratch {
        ExampleScratch {
            b,
            t,
            tokens: vec![0; b * t],
            targets: vec![0; b * t],
            mask: vec![0.0; b * t],
        }
    }

    /// Stage `ex` into the batch shape: every row carries the example's
    /// tokens/targets (valid ids everywhere), only row 0 carries its mask,
    /// so the in-graph masked mean equals the single example's loss.
    pub fn fill(&mut self, ex: &Example) {
        let t = self.t;
        debug_assert_eq!(ex.mask.len(), t, "example seq_len mismatch");
        for r in 0..self.b {
            self.tokens[r * t..(r + 1) * t].copy_from_slice(ex.tokens());
            self.targets[r * t..(r + 1) * t].copy_from_slice(ex.targets());
        }
        self.mask[..t].copy_from_slice(&ex.mask);
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.b, self.t)
    }

    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    pub fn targets(&self) -> &[i32] {
        &self.targets
    }

    pub fn mask(&self) -> &[f32] {
        &self.mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::eval_batches;
    use crate::data::corpus::make_dataset;
    use crate::runtime::Runtime;

    #[test]
    fn cache_uploads_each_batch_exactly_once() {
        let rt = Runtime::cpu().unwrap();
        let ds = make_dataset("medical", 512, 64, 64, 8, 4, 1).unwrap();
        let batches = eval_batches(&ds.val, 8);
        assert!(batches.iter().all(|(b, _)| b.mask.iter().sum::<f32>() > 0.0));
        let before = rt.stats.snapshot();
        let cache = EvalCache::build(&rt, &batches).unwrap();
        let d = rt.stats.snapshot().since(&before);
        assert_eq!(cache.len(), batches.len());
        // three uploads per chunk (tokens, targets, mask), and no more
        assert_eq!(d.uploads, 3 * batches.len() as u64);
        let expect_bytes: u64 = batches
            .iter()
            .map(|(b, _)| (b.tokens.len() + b.targets.len() + b.mask.len()) as u64 * 4)
            .sum();
        assert_eq!(d.uploaded_bytes, expect_bytes);
        // mask weights match the host batches
        for (chunk, (batch, _)) in cache.chunks().iter().zip(&batches) {
            let want: f32 = batch.mask.iter().sum();
            assert_eq!(chunk.mask_sum, want);
            assert_eq!(chunk.total_tokens, batch.total_tokens());
        }
    }

    #[test]
    fn all_padding_batches_are_never_uploaded() {
        let rt = Runtime::cpu().unwrap();
        let dead = Batch {
            b: 2,
            t: 4,
            tokens: vec![0; 8],
            targets: vec![0; 8],
            mask: vec![0.0; 8],
        };
        let live = Batch {
            b: 2,
            t: 4,
            tokens: vec![1; 8],
            targets: vec![1; 8],
            mask: vec![1.0; 8],
        };
        let before = rt.stats.snapshot();
        let cache = EvalCache::build(&rt, &[(dead, 0), (live, 2)]).unwrap();
        let d = rt.stats.snapshot().since(&before);
        assert_eq!(cache.len(), 1, "zero-mask chunk must be dropped at build");
        assert_eq!(d.uploads, 3);
    }

    #[test]
    fn loss_accum_weights_by_mask_sum() {
        let rt = Runtime::cpu().unwrap();
        let mk = |mask: Vec<f32>| EvalChunk {
            tokens: rt.upload_i32(&[0; 4], &[2, 2]).unwrap(),
            targets: rt.upload_i32(&[0; 4], &[2, 2]).unwrap(),
            mask: rt.upload_f32(&mask, &[2, 2]).unwrap(),
            mask_sum: mask.iter().sum(),
            total_tokens: 4,
        };
        let a = mk(vec![1.0; 4]); // weight 4
        let b = mk(vec![1.0, 0.0, 0.0, 0.0]); // weight 1
        let mut acc = LossAccum::new();
        acc.add(2.0, &a);
        acc.add(7.0, &b);
        assert_eq!(acc.tokens(), 8);
        let want = (2.0 * 4.0 + 7.0 * 1.0) / 5.0;
        assert!((acc.mean() as f64 - want).abs() < 1e-6, "{}", acc.mean());
        assert_eq!(LossAccum::new().mean(), 0.0, "empty accum is 0, not NaN");
    }

    #[test]
    fn scratch_replicates_rows_and_masks_only_row_zero() {
        let ds = make_dataset("medical", 512, 64, 64, 8, 4, 1).unwrap();
        let ex = &ds.test[0];
        let (b, t) = (4, ex.mask.len());
        let mut s = ExampleScratch::new(b, t);
        s.fill(ex);
        for r in 0..b {
            assert_eq!(&s.tokens()[r * t..(r + 1) * t], ex.tokens());
            assert_eq!(&s.targets()[r * t..(r + 1) * t], ex.targets());
        }
        assert_eq!(&s.mask()[..t], &ex.mask[..]);
        assert!(s.mask()[t..].iter().all(|&m| m == 0.0));
        // refill with a different example reuses the same buffers
        let ex2 = &ds.test[1];
        s.fill(ex2);
        assert_eq!(&s.tokens()[..t], ex2.tokens());
        assert!(s.mask()[t..].iter().all(|&m| m == 0.0));
    }
}
