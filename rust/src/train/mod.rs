//! Training coordination, split into policy over dispatch: the `Trainer`
//! schedule/run-loop policy, the `StepEngine` dispatch layer it drives
//! (program execution, donation chains, batch prefetch, deferred loss
//! readback — see `docs/step-pipeline.md`), checkpointing, and the
//! pretraining substrate that manufactures W0 for finetuning experiments.

pub mod batched;
pub mod checkpoint;
pub mod engine;
pub mod eval_cache;
pub mod pretrain;
pub mod trainer;

pub use batched::{pack_eligible, run_batched_group, MemberOutput, MemberSpec};
pub use engine::{Engine, EvalSplit, StepEngine, StepOptions};
pub use eval_cache::{EvalCache, ExampleScratch, LossAccum};
pub use trainer::{RunSummary, StopRule, Trainer};
