//! Training coordination: the `Trainer` run loop, checkpointing, and the
//! pretraining substrate that manufactures W0 for finetuning experiments.

pub mod checkpoint;
pub mod eval_cache;
pub mod pretrain;
pub mod trainer;

pub use eval_cache::{EvalCache, ExampleScratch};
pub use trainer::{RunSummary, StopRule, Trainer};
