//! The step engine: program dispatch, donated-buffer chaining, batch
//! prefetch, and deferred loss readback — everything between the schedule
//! policy ([`crate::train::trainer::Trainer`]) and the PJRT runtime.
//!
//! The training stack is three layers (see `docs/step-pipeline.md`):
//!
//! ```text
//!   Trainer (policy)      — FF decisions, stop rules, eval cadence, logs
//!      │  Engine trait (narrow: dispatch / sync / eval / snapshot)
//!   StepEngine (dispatch) — micro-batch loop, donation chains, prefetch,
//!      │                    per-run TransferMeter bookkeeping, Δ_W tracking
//!   ExecStream (stream)   — deferred loss readback ring
//! ```
//!
//! [`Engine::dispatch_step`] runs one Adam step *without* waiting for
//! its loss: `grad_step` executes in raw mode per micro-batch, loss
//! scalars stay on the device as [`PendingLoss`] handles, gradients fold
//! into the donated [`DeviceGradAccumulator`], and `adam_apply` retires
//! the step with every state buffer donated in place. Before returning,
//! the engine **prefetches** the next global batch through
//! [`BatchStager`] so its upload overlaps the in-flight device work, then
//! pushes the step's pending losses into the [`ExecStream`] ring — which
//! drains every K steps or at any forced boundary (FF stage, eval,
//! snapshot, shutdown). Dispatching this way removes every per-micro-batch
//! host synchronization from the steady-state hot loop while keeping the
//! transfer contract unchanged: batch bytes + one 4-byte step scalar up,
//! one 4-byte loss per micro back (later), zero state bytes either way.
//!
//! The [`Engine`] trait is the narrow surface the policy layer is written
//! against; FF line-search probes, analysis snapshots, and the experiment
//! pair-runs all reach the device through it, so there is exactly one
//! dispatch path to keep correct.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::data::batcher::{Batch, BatchStager, StagedBatch};
use crate::data::corpus::Example;
use crate::data::pipeline::Pipeline;
use crate::model::tensor::Tensor;
use crate::optim::accum::{DeviceGradAccumulator, GradAccumulator};
use crate::optim::delta::DeltaTracker;
use crate::runtime::{
    Artifact, ExecStream, InputBuf, Manifest, ParamSet, PendingLoss, PendingStep, Program,
    ResolvedStep, Runtime, StreamStats, SyncReason, TransferMeter, TransferSnapshot,
};
use crate::train::eval_cache::{EvalCache, ExampleScratch, LossAccum};

/// Default deferred-readback ring depth: losses are drained every K
/// dispatched steps unless a boundary forces an earlier sync.
pub const DEFAULT_DRAIN_INTERVAL: usize = 8;

/// Per-step knobs the policy layer passes down — the engine itself holds
/// no schedule state beyond the step counter.
#[derive(Debug, Clone, Copy)]
pub struct StepOptions {
    /// Learning rate for this step (the cached device scalar re-uploads
    /// when it changes — lr sweeps mutate it mid-run).
    pub lr: f32,
    /// Track Δ_W = W_t − W_{t−1} across this step (FF needs it; costs one
    /// trainable-set download per step).
    pub track_delta: bool,
    /// Keep every per-micro gradient host-side (Fig 13) — forces the host
    /// accumulation reference path.
    pub keep_micro_grads: bool,
    /// Download the mean gradient even when Δ_W tracking doesn't require
    /// it (Fig 6 cosine history).
    pub keep_host_grads: bool,
}

/// What one `dispatch_step` produced. The step's own loss is usually still
/// on the device — `resolved` carries whichever *earlier* steps the ring
/// chose to drain (possibly including this one, when the drain interval
/// was reached or the host path resolved synchronously).
pub struct StepDispatch {
    /// Monotone step id (the pre-step Adam counter); resolution is FIFO.
    pub ticket: u64,
    /// b·t token positions this step computed over (FLOPs charging).
    pub tokens: usize,
    /// Steps drained by this dispatch, in ticket order.
    pub resolved: Vec<ResolvedStep>,
    /// Mean gradient, host-side — non-empty iff the step downloaded it
    /// (host path, `track_delta`, or `keep_host_grads`).
    pub mean_grads: Vec<Tensor>,
    /// Per-micro gradients — non-empty iff `keep_micro_grads`.
    pub micro_grads: Vec<Vec<Tensor>>,
}

/// Which cached evaluation split to measure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalSplit {
    Val,
    Test,
}

/// One split (or example) evaluation: the token-weighted mean loss plus
/// the token positions computed over. FLOPs charging stays with the
/// policy layer — val probes bill as FF inference, test evals as
/// measurement — so the engine reports raw counts only.
#[derive(Debug, Clone, Copy)]
pub struct EvalMeasure {
    pub loss: f32,
    pub tokens: usize,
}

/// The narrow dispatch surface the policy layer (and everything above it:
/// line-search probes, experiments, benches) uses to reach the device.
pub trait Engine {
    /// Dispatch one Adam step over the next global batch. Does **not**
    /// wait for the step's loss unless the ring drains.
    fn dispatch_step(&mut self, opts: &StepOptions) -> Result<StepDispatch>;
    /// Force the deferred-readback ring to drain (FF boundary, eval,
    /// snapshot, shutdown, or a caller that needs a loss value now).
    fn sync(&mut self, reason: SyncReason) -> Result<Vec<ResolvedStep>>;
    /// Steps currently awaiting readback.
    fn pending_depth(&self) -> usize;
    /// Set the ring's drain interval (1 = fully synchronous).
    fn set_drain_interval(&mut self, k: usize);
    fn stream_stats(&self) -> &StreamStats;
    /// Adam steps dispatched so far.
    fn adam_steps(&self) -> usize;
    /// Token-weighted mean loss over a cached split (buffers upload once,
    /// on the first call, and are reused by every later probe).
    fn eval_split(&mut self, split: EvalSplit) -> Result<EvalMeasure>;
    /// Loss of a single example through the eval program (QA scoring).
    fn eval_example(&mut self, ex: &Example) -> Result<EvalMeasure>;
    /// Δ_W of the most recent tracked step, if any.
    fn delta(&self) -> Option<&[Tensor]>;
    /// `W += alpha·delta` on the live trainables (FF simulated step).
    fn axpy_trainables(&mut self, alpha: f32, delta: &[Tensor]) -> Result<()>;
    /// Trainable tensor shapes — **no** device→host sync (geometry is
    /// fixed at construction). Callers that only need sizes for probe
    /// directions or log lines must use this, not a snapshot.
    fn trainable_shapes(&self) -> Vec<Vec<usize>>;
    /// Number of trainable tensors (sync-free).
    fn trainable_count(&self) -> usize;
    /// Total trainable elements (sync-free).
    fn trainable_numel(&self) -> usize;
    /// Current trainable values (W_t), lazily downloading only the
    /// device-ahead tensors of the trainable set.
    fn trainable_snapshot(&mut self) -> Result<Vec<Tensor>>;
    /// Overwrite the trainables from a snapshot (host becomes
    /// authoritative).
    fn restore_trainables(&mut self, snap: &[Tensor]);
    /// All parameters by name (checkpointing). Downloads lazily and only
    /// the trainable set — frozen params are never device-written.
    fn named_params(&mut self) -> Result<BTreeMap<String, Tensor>>;
    /// Host↔device traffic attributable to this engine since
    /// construction, read from the engine's own [`TransferMeter`] —
    /// **exact** even while sibling runs share the runtime
    /// (`docs/transfer-contract.md` §5).
    fn transfers(&self) -> TransferSnapshot;
    /// (uploads, downloads) summed over the trainable/m/v ParamSets.
    fn state_transfer_counts(&self) -> (u64, u64);
    /// Full optimizer state `(trainables, m, v)` host-side — the park half
    /// of the queue's preempt/park/resume cycle. Downloads only the
    /// device-ahead tensors of each set (3·|trainable| in steady state,
    /// since m/v live device-only for the life of a run).
    fn state_snapshot(&mut self) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)>;
    /// Overwrite the full optimizer state from a park snapshot and set the
    /// Adam step counter (the step scalar uploaded each dispatch derives
    /// from it, so bias correction continues exactly where the parked run
    /// left off). Host becomes authoritative; any tracked Δ_W is dropped.
    fn restore_state(&mut self, tr: &[Tensor], m: &[Tensor], v: &[Tensor], adam_steps: usize);
    /// Discard the next `n` pipeline batches — a resumed run fast-forwards
    /// its deterministic data stream past the batches the parked run
    /// already consumed. Host-side only: nothing is staged or uploaded.
    fn skip_batches(&mut self, n: usize) -> Result<()>;
    /// Number of frozen tensors (sync-free; resume byte accounting).
    fn frozen_count(&self) -> usize;
    /// Total frozen elements (sync-free; resume byte accounting).
    fn frozen_numel(&self) -> usize;
    /// LoFT-style optimizer-state realignment (`OptimBackend::Loft`,
    /// applied by the trainer after each FF stage): `m *= decay`,
    /// `v *= decay²`. Dispatches the artifact's `loft_realign` program
    /// with both moment sets donated in place when the manifest carries
    /// it; otherwise falls back to a host-side scale (the next dispatch
    /// re-uploads the moments — correct, just not transfer-free).
    fn loft_realign(&mut self, decay: f32) -> Result<()>;
}

/// How a step's micro losses come back: deferred device buffers (device
/// accumulation) or values the decoded host path already holds.
enum StepLosses {
    Deferred(Vec<PendingLoss>),
    Immediate { mean_loss: f32, micro_losses: Vec<f32> },
}

/// The concrete engine (see module docs). One engine = one run: every
/// mutable thing here (ParamSets, stager, ring, caches, scalar buffers) is
/// owned by the run's worker thread; the `Arc`s (runtime, artifact,
/// compiled programs) are the read-only state shared across concurrent
/// runs by the scheduler (`docs/transfer-contract.md` §5).
pub struct StepEngine {
    rt: Arc<Runtime>,
    art: Arc<Artifact>,
    // parameter + optimizer state
    tr: ParamSet,
    fr: ParamSet,
    m: ParamSet,
    v: ParamSet,
    adam_steps: usize,
    // programs
    grad_prog: Arc<Program>,
    adam_prog: Arc<Program>,
    eval_prog: Arc<Program>,
    /// Device-side accumulation pair (`grad_accum`/`grad_finalize`);
    /// `None` for artifacts that predate them — the engine then falls back
    /// to the host [`GradAccumulator`] path.
    grad_accum_prog: Option<Arc<Program>>,
    grad_finalize_prog: Option<Arc<Program>>,
    /// Cached learning-rate scalar buffer, keyed by the lr value it holds.
    lr_buf: Option<(f32, xla::PjRtBuffer)>,
    /// Cached `1/n_micro` scalar for `grad_finalize`, keyed by micro count.
    inv_n_buf: Option<(usize, xla::PjRtBuffer)>,
    // pipeline
    pipeline: Pipeline,
    stager: BatchStager,
    stream: ExecStream,
    delta: DeltaTracker,
    // eval
    val_batches: Vec<(Batch, usize)>,
    test_batches: Vec<(Batch, usize)>,
    val_cache: Option<EvalCache>,
    test_cache: Option<EvalCache>,
    qa_scratch: Option<ExampleScratch>,
    // accounting
    /// This run's exact transfer meter: every upload/download/donation
    /// the engine (or a component it owns — ParamSets, stager, eval
    /// caches, pending losses) performs is tallied here in addition to
    /// the shared `Runtime::stats`, so per-run totals are exact at any
    /// `--jobs` level (no sibling traffic, unlike a window over the
    /// shared meters).
    meter: Arc<TransferMeter>,
}

/// Both halves of the optional device-side accumulation pair, or neither
/// — a manifest with only one of them is malformed enough to fall back to
/// the host path rather than half-commit.
fn has_device_accum_pair(man: &Manifest) -> bool {
    man.has_program("grad_accum") && man.has_program("grad_finalize")
}

/// Exactly the programs [`StepEngine::new`] compiles for `manifest`: the
/// required trio plus the device-accumulation pair when the manifest
/// carries both halves. Pre-warm loops (the scheduler-scaling section of
/// `bench_rank_sweep`) iterate this so a shared program cache is primed
/// with the same set a fresh engine will request — keep it in lockstep
/// with [`StepEngine::new`] below.
pub fn required_programs(manifest: &Manifest) -> Vec<&'static str> {
    let mut progs = vec!["grad_step", "adam_apply", "eval_loss"];
    if has_device_accum_pair(manifest) {
        progs.extend(["grad_accum", "grad_finalize"]);
    }
    progs
}

impl StepEngine {
    /// Build an engine over an artifact: parameter sets from `values`,
    /// compiled programs (the set [`required_programs`] names), an empty
    /// stager/ring. `pipeline` is the batch producer the stager pulls
    /// from.
    pub fn new(
        rt: &Arc<Runtime>,
        art: Arc<Artifact>,
        values: &BTreeMap<String, Tensor>,
        pipeline: Pipeline,
        val_batches: Vec<(Batch, usize)>,
        test_batches: Vec<(Batch, usize)>,
    ) -> Result<StepEngine> {
        let man = &art.manifest;
        let meter = TransferMeter::new();
        let mut tr = ParamSet::from_spec(rt, &man.trainable, values)?;
        let mut fr = ParamSet::from_spec(rt, &man.frozen, values)?;
        let mut m = ParamSet::zeros_like(rt, &tr);
        let mut v = ParamSet::zeros_like(rt, &tr);
        tr.attach_meter(&meter);
        fr.attach_meter(&meter);
        m.attach_meter(&meter);
        v.attach_meter(&meter);
        let grad_prog = art.program("grad_step")?;
        let adam_prog = art.program("adam_apply")?;
        let eval_prog = art.program("eval_loss")?;
        let (grad_accum_prog, grad_finalize_prog) = if has_device_accum_pair(man) {
            (Some(art.program("grad_accum")?), Some(art.program("grad_finalize")?))
        } else {
            (None, None)
        };
        let stager = BatchStager::with_meter(rt, &meter);
        Ok(StepEngine {
            rt: Arc::clone(rt),
            art,
            tr,
            fr,
            m,
            v,
            adam_steps: 0,
            grad_prog,
            adam_prog,
            eval_prog,
            grad_accum_prog,
            grad_finalize_prog,
            lr_buf: None,
            inv_n_buf: None,
            pipeline,
            stager,
            stream: ExecStream::new(DEFAULT_DRAIN_INTERVAL),
            delta: DeltaTracker::new(),
            val_batches,
            test_batches,
            val_cache: None,
            test_cache: None,
            qa_scratch: None,
            meter,
        })
    }

    /// Device path: `grad_step` in raw mode per micro-batch — the loss
    /// scalar stays on the device as a [`PendingLoss`], the gradient
    /// buffers fold into the donated [`DeviceGradAccumulator`] — then one
    /// `grad_finalize` returns the mean-gradient buffers ready to donate
    /// into `adam_apply`.
    fn accumulate_device(
        &mut self,
        staged: &StagedBatch,
    ) -> Result<(Vec<xla::PjRtBuffer>, Vec<PendingLoss>)> {
        let accum_prog =
            Arc::clone(self.grad_accum_prog.as_ref().expect("checked by dispatch_step"));
        let finalize_prog =
            Arc::clone(self.grad_finalize_prog.as_ref().expect("checked by dispatch_step"));
        let n = self.tr.len();
        let mut acc = DeviceGradAccumulator::new();
        let mut pending = Vec::with_capacity(staged.micro.len());
        for micro in &staged.micro {
            let inputs = param_batch_inputs(
                &mut self.tr,
                &mut self.fr,
                self.grad_prog.spec.inputs.len(),
                [&micro.tokens, &micro.targets, &micro.mask],
            )?;
            let outs = self.grad_prog.execute_raw(&inputs)?;
            drop(inputs);
            let mut outs = outs.into_iter();
            let loss_buf = outs.next().expect("grad_step outputs [loss, g..]");
            pending.push(PendingLoss::metered(&self.grad_prog, loss_buf, 0, &self.meter));
            let grads: Vec<xla::PjRtBuffer> = outs.collect();
            // Hard assert: arity drift against the manifest would adopt
            // gradients under the wrong parameter names downstream.
            assert_eq!(grads.len(), n, "grad_step output arity");
            acc.add_raw_bufs(&accum_prog, grads, Some(&self.meter))?;
        }
        let count = acc.count();
        if self.inv_n_buf.as_ref().map(|(c, _)| *c) != Some(count) {
            let buf = self.meter.upload_scalar(&self.rt, 1.0 / count as f32)?;
            self.inv_n_buf = Some((count, buf));
        }
        let bufs = acc.finalize_bufs(
            &finalize_prog,
            &self.inv_n_buf.as_ref().unwrap().1,
            Some(&self.meter),
        )?;
        Ok((bufs, pending))
    }

    /// Host reference path (`keep_micro_grads`, or artifacts without the
    /// accumulation programs): decode every micro gradient, accumulate in
    /// the host [`GradAccumulator`]. Losses resolve synchronously here —
    /// the decoded execution downloads everything anyway.
    fn accumulate_host(
        &mut self,
        staged: &StagedBatch,
        keep_micro_grads: bool,
    ) -> Result<(Vec<Tensor>, Vec<Vec<Tensor>>, f32, Vec<f32>)> {
        let n = self.tr.len();
        let shapes = self.tr.shapes();
        let mut acc = GradAccumulator::new(&shapes);
        let mut micro_grads = Vec::new();
        let mut micro_losses = Vec::with_capacity(staged.micro.len());
        for micro in &staged.micro {
            let inputs = param_batch_inputs(
                &mut self.tr,
                &mut self.fr,
                self.grad_prog.spec.inputs.len(),
                [&micro.tokens, &micro.targets, &micro.mask],
            )?;
            // Gradients are consumed host-side here, so the decoded path
            // is the right one.
            let out = self.grad_prog.execute_buffers_metered(&inputs, Some(&self.meter))?;
            let loss = out.values[0][0];
            micro_losses.push(loss);
            let grads: Vec<&[f32]> =
                (0..n).map(|i| out.values[1 + i].as_slice()).collect();
            acc.add_flat(&grads, loss);
            if keep_micro_grads {
                micro_grads.push(
                    (0..n)
                        .map(|i| Tensor::from_vec(&shapes[i], out.values[1 + i].clone()))
                        .collect(),
                );
            }
        }
        let (mean, mean_loss) = acc.take_mean();
        Ok((mean, micro_grads, mean_loss, micro_losses))
    }

    /// Download mean-gradient buffers into host tensors (Δ_W stats and
    /// analysis consumers only — the dispatch path never needs this).
    fn download_grads(&self, bufs: &[xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(bufs.len());
        for (i, b) in bufs.iter().enumerate() {
            let v = self.meter.download_f32(&self.rt, b)?;
            out.push(Tensor::from_vec(self.tr.shape(i), v));
        }
        Ok(out)
    }

    fn eval_cached(&mut self, cache: &EvalCache) -> Result<EvalMeasure> {
        let mut acc = LossAccum::new();
        for chunk in cache.chunks() {
            debug_assert!(chunk.mask_sum > 0.0, "EvalCache::build drops zero-mask chunks");
            let inputs = param_batch_inputs(
                &mut self.tr,
                &mut self.fr,
                self.eval_prog.spec.inputs.len(),
                [&chunk.tokens, &chunk.targets, &chunk.mask],
            )?;
            let out = self.eval_prog.execute_buffers_metered(&inputs, Some(&self.meter))?;
            acc.add(out.values[0][0], chunk);
        }
        Ok(EvalMeasure { loss: acc.mean(), tokens: acc.tokens() })
    }
}

impl Engine for StepEngine {
    fn dispatch_step(&mut self, opts: &StepOptions) -> Result<StepDispatch> {
        // The batch for this step: prefetched during the previous step in
        // steady state, staged inline on the first step.
        let staged = {
            let stager = &mut self.stager;
            let pipeline = &mut self.pipeline;
            stager.take_or_stage(|| pipeline.next())?
        };
        let ticket = self.adam_steps as u64;
        let use_device_accum = self.grad_accum_prog.is_some() && !opts.keep_micro_grads;

        let mut mean_grads: Vec<Tensor> = Vec::new();
        let mut micro_grads: Vec<Vec<Tensor>> = Vec::new();
        let (g_bufs, losses) = if use_device_accum {
            let (bufs, pending) = self.accumulate_device(&staged)?;
            // FF stage stats need ‖g‖ host-side; Fig 6 asks via
            // keep_host_grads. Everyone else skips the download.
            if opts.track_delta || opts.keep_host_grads {
                mean_grads = self.download_grads(&bufs)?;
            }
            (bufs, StepLosses::Deferred(pending))
        } else {
            let (mean, micros, mean_loss, micro_losses) =
                self.accumulate_host(&staged, opts.keep_micro_grads)?;
            let bufs: Vec<xla::PjRtBuffer> = mean
                .iter()
                .map(|g| self.meter.upload_tensor(&self.rt, g))
                .collect::<Result<_>>()?;
            mean_grads = mean;
            micro_grads = micros;
            (bufs, StepLosses::Immediate { mean_loss, micro_losses })
        };

        // Adam apply on device. W_{t−1} comes from the host view, which
        // the sync API pulls fresh on demand.
        if opts.track_delta {
            self.delta.begin_step(&mut self.tr)?;
        }
        let step_buf = self.meter.upload_scalar(&self.rt, self.adam_steps as f32)?;
        if self.lr_buf.as_ref().map(|(v, _)| *v) != Some(opts.lr) {
            self.lr_buf = Some((opts.lr, self.meter.upload_scalar(&self.rt, opts.lr)?));
        }
        // Donated dispatch: trainable/m/v and the mean gradient hand their
        // buffers over; adam_apply's alias map reuses the allocations in
        // place and the outputs are adopted straight back.
        let tr_bufs = self.tr.take_device_buffers()?;
        let m_bufs = self.m.take_device_buffers()?;
        let v_bufs = self.v.take_device_buffers()?;
        let mut inputs: Vec<InputBuf> = Vec::with_capacity(self.adam_prog.spec.inputs.len());
        inputs.extend(tr_bufs.into_iter().map(InputBuf::Donated));
        inputs.extend(m_bufs.into_iter().map(InputBuf::Donated));
        inputs.extend(v_bufs.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&step_buf));
        inputs.extend(g_bufs.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&self.lr_buf.as_ref().unwrap().1));
        let outs = self.adam_prog.execute_raw_donated_metered(inputs, Some(&self.meter))?;
        let mut outs = outs.into_iter();
        self.tr.adopt_all(&mut outs)?;
        self.m.adopt_all(&mut outs)?;
        self.v.adopt_all(&mut outs)?;
        // Δ_W = W_t − W_{t−1} needs W_t host-side: lazily sync just the
        // trainables (m/v stay device-only for the life of the run).
        if opts.track_delta {
            self.delta.end_step(&mut self.tr)?;
        } else {
            // a Δ from before FF shut off must not be served later
            self.delta.clear();
        }
        self.adam_steps += 1;

        // Prefetch: upload the *next* step's batch while this step's
        // programs are still retiring on the device.
        {
            let stager = &mut self.stager;
            let pipeline = &mut self.pipeline;
            stager.prefetch(|| pipeline.next())?;
        }

        let mut resolved = Vec::new();
        match losses {
            StepLosses::Deferred(pending) => {
                resolved.extend(self.stream.push(PendingStep::new(ticket, pending))?);
            }
            StepLosses::Immediate { mean_loss, micro_losses } => {
                // The host path already holds its loss: retire any older
                // deferred steps first so tickets stay FIFO, then append.
                // The step never enters the ring but still counts.
                resolved.extend(self.stream.sync(SyncReason::StepResult)?);
                self.stream.record_passthrough();
                resolved.push(ResolvedStep { ticket, mean_loss, micro_losses });
            }
        }

        Ok(StepDispatch {
            ticket,
            tokens: staged.total_tokens,
            resolved,
            mean_grads,
            micro_grads,
        })
    }

    fn sync(&mut self, reason: SyncReason) -> Result<Vec<ResolvedStep>> {
        self.stream.sync(reason)
    }

    fn pending_depth(&self) -> usize {
        self.stream.depth()
    }

    fn set_drain_interval(&mut self, k: usize) {
        self.stream.set_drain_interval(k);
    }

    fn stream_stats(&self) -> &StreamStats {
        self.stream.stats()
    }

    fn adam_steps(&self) -> usize {
        self.adam_steps
    }

    fn eval_split(&mut self, split: EvalSplit) -> Result<EvalMeasure> {
        // Detach the cache from `self` so iterating it doesn't pin a
        // borrow across the &mut self program calls; re-attached below.
        let cache = match split {
            EvalSplit::Val => self.val_cache.take(),
            EvalSplit::Test => self.test_cache.take(),
        };
        let cache = match cache {
            Some(c) => c,
            None => {
                let batches = match split {
                    EvalSplit::Val => &self.val_batches,
                    EvalSplit::Test => &self.test_batches,
                };
                EvalCache::build_metered(&self.rt, Some(&self.meter), batches)?
            }
        };
        let result = self.eval_cached(&cache);
        match split {
            EvalSplit::Val => self.val_cache = Some(cache),
            EvalSplit::Test => self.test_cache = Some(cache),
        }
        result
    }

    fn eval_example(&mut self, ex: &Example) -> Result<EvalMeasure> {
        let (b, t) = {
            let mc = &self.art.manifest.config.model;
            (mc.eval_batch, mc.seq_len)
        };
        ensure!(ex.mask.len() == t, "example seq_len {} != model {}", ex.mask.len(), t);
        let scratch = self.qa_scratch.get_or_insert_with(|| ExampleScratch::new(b, t));
        scratch.fill(ex);
        let tok = self.meter.upload_i32(&self.rt, scratch.tokens(), &[b, t])?;
        let tgt = self.meter.upload_i32(&self.rt, scratch.targets(), &[b, t])?;
        let msk = self.meter.upload_f32(&self.rt, scratch.mask(), &[b, t])?;
        let inputs = param_batch_inputs(
            &mut self.tr,
            &mut self.fr,
            self.eval_prog.spec.inputs.len(),
            [&tok, &tgt, &msk],
        )?;
        let out = self.eval_prog.execute_buffers_metered(&inputs, Some(&self.meter))?;
        Ok(EvalMeasure { loss: out.values[0][0], tokens: b * t })
    }

    fn delta(&self) -> Option<&[Tensor]> {
        self.delta.delta()
    }

    fn axpy_trainables(&mut self, alpha: f32, delta: &[Tensor]) -> Result<()> {
        // Read-modify-write: make the host view fresh first (no-op when
        // the previous step already synced it for Δ_W).
        self.tr.sync_host()?;
        self.tr.axpy(alpha, delta);
        Ok(())
    }

    fn trainable_shapes(&self) -> Vec<Vec<usize>> {
        self.tr.shapes()
    }

    fn trainable_count(&self) -> usize {
        self.tr.len()
    }

    fn trainable_numel(&self) -> usize {
        self.tr.numel()
    }

    fn trainable_snapshot(&mut self) -> Result<Vec<Tensor>> {
        self.tr.sync_host()?;
        Ok(self.tr.snapshot())
    }

    fn restore_trainables(&mut self, snap: &[Tensor]) {
        self.tr.restore(snap);
    }

    fn named_params(&mut self) -> Result<BTreeMap<String, Tensor>> {
        // Only the trainable set can be device-ahead; frozen params are
        // never device-written, so no sync (hence no download) for them.
        self.tr.sync_host()?;
        let mut out = BTreeMap::new();
        for (name, t) in self.tr.names().iter().zip(self.tr.tensors()) {
            out.insert(name.clone(), t.clone());
        }
        for (name, t) in self.fr.names().iter().zip(self.fr.tensors()) {
            out.insert(name.clone(), t.clone());
        }
        Ok(out)
    }

    fn transfers(&self) -> TransferSnapshot {
        self.meter.snapshot()
    }

    fn state_transfer_counts(&self) -> (u64, u64) {
        (
            self.tr.upload_count() + self.m.upload_count() + self.v.upload_count(),
            self.tr.download_count() + self.m.download_count() + self.v.download_count(),
        )
    }

    fn state_snapshot(&mut self) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>)> {
        self.tr.sync_host()?;
        self.m.sync_host()?;
        self.v.sync_host()?;
        Ok((self.tr.snapshot(), self.m.snapshot(), self.v.snapshot()))
    }

    fn restore_state(&mut self, tr: &[Tensor], m: &[Tensor], v: &[Tensor], adam_steps: usize) {
        self.tr.restore(tr);
        self.m.restore(m);
        self.v.restore(v);
        self.adam_steps = adam_steps;
        // Δ_W from before the restore must not be served after it.
        self.delta.clear();
    }

    fn skip_batches(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            let _ = self.pipeline.next();
        }
        Ok(())
    }

    fn frozen_count(&self) -> usize {
        self.fr.len()
    }

    fn frozen_numel(&self) -> usize {
        self.fr.numel()
    }

    fn loft_realign(&mut self, decay: f32) -> Result<()> {
        if self.art.manifest.has_program("loft_realign") {
            // Device path: donated in place, zero state bytes moved. The
            // program is fetched lazily — baseline Adam runs on the same
            // artifact never compile it.
            let prog = self.art.program("loft_realign")?;
            let decay_buf = self.meter.upload_scalar(&self.rt, decay)?;
            let m_bufs = self.m.take_device_buffers()?;
            let v_bufs = self.v.take_device_buffers()?;
            let mut inputs: Vec<InputBuf> = Vec::with_capacity(prog.spec.inputs.len());
            inputs.extend(m_bufs.into_iter().map(InputBuf::Donated));
            inputs.extend(v_bufs.into_iter().map(InputBuf::Donated));
            inputs.push(InputBuf::Borrowed(&decay_buf));
            let outs = prog.execute_raw_donated_metered(inputs, Some(&self.meter))?;
            let mut outs = outs.into_iter();
            self.m.adopt_all(&mut outs)?;
            self.v.adopt_all(&mut outs)?;
        } else {
            // Host fallback for artifacts emitted before the program
            // existed: scale the synced moment tensors; the restore makes
            // the host authoritative, so the next dispatch re-uploads.
            self.m.sync_host()?;
            self.v.sync_host()?;
            let scale = |ts: &[Tensor], k: f32| -> Vec<Tensor> {
                ts.iter()
                    .map(|t| {
                        let mut t = t.clone();
                        t.data.iter_mut().for_each(|x| *x *= k);
                        t
                    })
                    .collect()
            };
            let m_scaled = scale(self.m.tensors(), decay);
            let v_scaled = scale(self.v.tensors(), decay * decay);
            self.m.restore(&m_scaled);
            self.v.restore(&v_scaled);
        }
        Ok(())
    }
}

/// Assemble the `[trainables.., frozen.., tokens, targets, mask]` input
/// list shared by every `grad_step`/`eval_loss` dispatch, uploading any
/// stale parameter tensors first. A free function over the two ParamSets
/// (not a `&mut self` method) so the returned borrows stay field-scoped
/// and the caller can still dispatch through the engine's program handles.
fn param_batch_inputs<'a>(
    tr: &'a mut ParamSet,
    fr: &'a mut ParamSet,
    arity: usize,
    batch: [&'a xla::PjRtBuffer; 3],
) -> Result<Vec<&'a xla::PjRtBuffer>> {
    let mut inputs = Vec::with_capacity(arity);
    inputs.extend(tr.device_buffers()?);
    inputs.extend(fr.device_buffers()?);
    inputs.extend(batch);
    Ok(inputs)
}
