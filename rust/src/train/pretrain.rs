//! Pretraining substrate: the paper finetunes *pretrained* LMs (Pythia,
//! Llama-3). No checkpoints exist for our substitute models, so we
//! manufacture W0 by briefly training each model full-rank (`full_all`
//! artifact) on the wide-distribution "pile" task, then cache the result
//! under `artifacts/checkpoints/`. Every finetuning experiment starts from
//! this cached W0 — the baseline and FF runs of an experiment therefore
//! share their starting point exactly, as in the paper.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{Context, Result};

use crate::config::{presets, FfConfig, TrainConfig};
use crate::model::tensor::Tensor;
use crate::runtime::Runtime;
use crate::train::checkpoint::{load_params, save_params};
use crate::train::trainer::{StopRule, Trainer};

pub fn checkpoint_path(artifacts_root: &Path, model: &str) -> PathBuf {
    artifacts_root.join("checkpoints").join(format!("{model}_w0.ffck"))
}

/// Default pretraining length per model (steps of global batch 32). Scaled
/// so the tiny grid models pretrain in seconds-to-minutes on one core.
pub fn default_pretrain_steps(model: &str) -> usize {
    match model {
        "ff-tiny" => 120,
        "ff-small" => 80,
        "ff-medium" => 50,
        "ff-large" => 30,
        _ => 20,
    }
}

/// Serializes checkpoint builds: concurrent scheduler workers may reach
/// `ensure_pretrained` for the same model at the same time; exactly one
/// may train-and-cache while the rest wait and then read the cache. The
/// pretraining run is itself a full training run, so serializing the whole
/// build (rather than just the file write) also keeps it deterministic.
static PRETRAIN_BUILD: Mutex<()> = Mutex::new(());

/// Load the cached pretrained W0 for `model`, training and caching it on
/// first use. Returns all base parameters by name. Safe to call from
/// concurrent worker threads: the fast path is a lock-free cache read; the
/// build path is serialized process-wide and the checkpoint file is
/// written atomically (`save_params` writes temp-then-rename).
pub fn ensure_pretrained(
    rt: &Arc<Runtime>,
    artifacts_root: &Path,
    model: &str,
    steps: Option<usize>,
) -> Result<BTreeMap<String, Tensor>> {
    ensure_pretrained_via(rt, artifacts_root, model, steps, None)
}

/// Store-backed ref name for a W0 checkpoint. Pinned to the step count so
/// a grid pretrained at a non-default length never aliases the default.
fn w0_ref_name(model: &str, steps: Option<usize>) -> String {
    let steps = steps.unwrap_or_else(|| default_pretrain_steps(model));
    format!("w0/{model}-{steps}")
}

/// [`ensure_pretrained`] with an optional content-addressed store
/// (`docs/artifact-store.md`). Resolution order:
///
/// 1. local checkpoint file — load it, and (idempotently) publish its
///    bytes to the store so other hosts can fetch instead of rebuild;
/// 2. store fetch by ref `w0/<model>-<steps>` — verified by content hash,
///    materialized to the local checkpoint path temp-then-rename;
/// 3. build from scratch (counted via `StoreStats::w0_builds`), then save
///    locally *and* publish to the store.
///
/// All store I/O is host-disk traffic: it never touches device transfer
/// meters (`docs/transfer-contract.md`).
pub fn ensure_pretrained_via(
    rt: &Arc<Runtime>,
    artifacts_root: &Path,
    model: &str,
    steps: Option<usize>,
    store: Option<&crate::store::ArtifactStore>,
) -> Result<BTreeMap<String, Tensor>> {
    let path = checkpoint_path(artifacts_root, model);
    if path.exists() {
        let params = load_params(&path).with_context(|| format!("cached W0 for {model}"))?;
        if let Some(s) = store {
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading {} for store publish", path.display()))?;
            s.publish_checkpoint(&w0_ref_name(model, steps), &bytes)?;
        }
        return Ok(params);
    }
    // Cache miss: take the build lock, then re-check — another worker may
    // have finished the identical build while we waited.
    let _build = PRETRAIN_BUILD.lock().unwrap_or_else(PoisonError::into_inner);
    if path.exists() {
        return load_params(&path).with_context(|| format!("cached W0 for {model}"));
    }
    // No local file: try the store before paying for a rebuild. A corrupt
    // store object is quarantined inside `fetch_checkpoint` and surfaces
    // here as `None`, so we fall through to an honest rebuild.
    if let Some(s) = store {
        if let Some(bytes) = s.fetch_checkpoint(&w0_ref_name(model, steps))? {
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir)?;
            }
            let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
            std::fs::write(&tmp, &bytes)?;
            std::fs::rename(&tmp, &path)?;
            return load_params(&path).with_context(|| format!("store-fetched W0 for {model}"));
        }
        s.note_w0_build();
    }
    let steps = steps.unwrap_or_else(|| default_pretrain_steps(model));
    crate::info!("pretraining {model} for {steps} steps (full_all on 'pile') → {}", path.display());

    let tp = presets::task_preset("pile")?;
    let cfg = TrainConfig {
        artifact: format!("{model}_full_all"),
        task: "pile".into(),
        lr: tp.lr,
        global_batch: tp.global_batch,
        max_steps: steps,
        seed: 0x11e, // fixed: W0 must be identical across experiments
        ff: FfConfig { enabled: false, ..FfConfig::default() },
        adam: Default::default(),
        backend: Default::default(),
        loft_decay: 0.5,
        train_examples: tp.train_examples,
        test_examples: 64,
    };
    let mut t = Trainer::new(rt, artifacts_root, cfg, None)?;
    let summary = t.run(&StopRule::MaxSteps(steps))?;
    crate::info!(
        "pretrained {model}: test loss {:.4} after {} steps",
        summary.final_test_loss,
        summary.adam_steps
    );
    let params = t.all_params()?;
    save_params(&path, &params)?;
    if let Some(s) = store {
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {} for store publish", path.display()))?;
        s.publish_checkpoint(&w0_ref_name(model, Some(steps)), &bytes)?;
    }
    Ok(params)
}
