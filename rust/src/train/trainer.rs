//! The training **policy** layer: Fast Forward scheduling, stop rules,
//! eval cadence, FLOPs/time accounting, and the run log.
//!
//! One `Trainer` = one run (one artifact, one task, one FfConfig). The
//! experiment harnesses construct pairs of trainers (baseline vs FF) over
//! identical data and compare FLOPs/time to matched test loss.
//!
//! # Three layers (see `docs/step-pipeline.md`)
//!
//! Everything that touches the device lives below this file:
//!
//! * [`StepEngine`](crate::train::engine::StepEngine) owns program
//!   dispatch, donated-buffer chaining, batch prefetch, Δ_W tracking, the
//!   eval caches, and all `TransferStats` bookkeeping. The trainer calls
//!   it exclusively through the narrow [`Engine`] trait, so FF line-search
//!   probes and the experiment pair-runs go through the same dispatch
//!   path as the run loop.
//! * The engine's [`ExecStream`](crate::runtime::ExecStream) defers loss
//!   readback: a dispatched step's per-micro loss scalars stay on the
//!   device until the ring drains — every K steps, or at a forced
//!   boundary (FF stage, eval, snapshot, shutdown). The trainer keeps a
//!   FIFO of *pending step records* and backfills each one's loss into
//!   [`RunLog`] when its step resolves, so the log is identical to the
//!   synchronous one, just written later.
//!
//! [`Trainer::sgd_step`] is the synchronous wrapper (dispatch + immediate
//! drain — the old behaviour, bit-for-bit); [`Trainer::dispatch_sgd_step`]
//! is the pipelined half that [`Trainer::run`] and the benches use to keep
//! several steps in flight. The host↔device movement rules are documented
//! in `docs/transfer-contract.md`; the steady-state contract (batch bytes
//! + one 4-byte step scalar up, one 4-byte loss per micro down) is
//! unchanged by pipelining — only *when* the loss bytes cross moves.

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::analysis::linalg::mean_condition_number;
use crate::config::{OptimBackend, TrainConfig};
use crate::data::batcher::eval_batches;
use crate::data::corpus::{make_dataset, Dataset};
use crate::data::pipeline::Pipeline;
use crate::ff::controller::{FfController, FfDecision, FfStageStats};
use crate::ff::line_search::{line_search_thresholded, LineSearchResult, SearchTarget};
use crate::flops::{FlopsCounter, FlopsModel};
use crate::metrics::{RunLog, StepKind, StepRecord, TrainTimer};
use crate::model::init::{init_params, init_with_base};
use crate::model::tensor::{list_norm, Tensor};
use crate::runtime::{Artifact, ResolvedStep, Runtime, StreamStats, SyncReason, TransferSnapshot};
use crate::train::checkpoint::ParkState;
use crate::train::engine::{Engine, EvalSplit, StepEngine, StepOptions};

/// When to stop a training run.
#[derive(Debug, Clone)]
pub enum StopRule {
    /// Fixed number of Adam steps (the 5-epoch baseline protocol).
    MaxSteps(usize),
    /// Stop once test loss ≤ target + eps, checking every `eval_every`
    /// Adam steps (the FF run's "match the baseline" protocol, §4).
    TargetLoss { target: f32, eps: f32, eval_every: usize, max_steps: usize },
    /// Run until the controller turns FF off permanently (§5.1), then a
    /// final `tail` SGD steps.
    Convergence { max_steps: usize, tail: usize },
}

#[derive(Debug, Clone)]
pub struct RunSummary {
    pub final_test_loss: f32,
    pub adam_steps: usize,
    pub sim_steps: usize,
    pub flops: FlopsCounter,
    pub train_seconds: f64,
    pub reached_target: bool,
    /// True when [`Trainer::run`] stopped early because the cooperative
    /// cancel flag ([`Trainer::set_cancel_flag`]) was set: the run halted
    /// at the next step boundary, drained its pipeline, and evaluated —
    /// the summary describes a consistent partial run, not an error.
    pub cancelled: bool,
    /// True when [`Trainer::run`] stopped at a step boundary because a
    /// park request landed ([`Trainer::set_park_flag`]) or the step
    /// quantum elapsed ([`Trainer::set_step_quantum`]). The run is
    /// *incomplete by design*: call [`Trainer::park_state`] to snapshot
    /// it, resume later via [`Trainer::resume_from`] on a fresh trainer.
    /// `final_test_loss` is NaN — a parked run never runs the final eval.
    pub parked: bool,
    /// Host↔device traffic attributable to this trainer since
    /// construction (uploads/downloads/donations, calls and bytes), read
    /// from the engine's own `TransferMeter` — exact even while sibling
    /// runs share the runtime (see runtime §Perf counters and
    /// `docs/transfer-contract.md` §5).
    pub transfers: TransferSnapshot,
    /// Artifact-store traffic window for this run's slot, filled in by the
    /// scheduler when an [`crate::store::ArtifactStore`] is attached to the
    /// [`crate::sched::ArtifactCache`] (else `None`). The window is exact
    /// at `--jobs 1`; under concurrency sibling slots share the store's
    /// counters, so treat it as "store activity while this run executed".
    /// Store I/O is host-disk traffic and never touches `transfers`.
    pub store: Option<crate::store::StoreSnapshot>,
}

/// A dispatched step whose loss has not come back yet: everything the
/// [`StepRecord`] needs except the loss, stamped at dispatch time.
struct PendingRecord {
    ticket: u64,
    step: usize,
    flops: u64,
    seconds: f64,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub art: Arc<Artifact>,
    /// The dispatch layer (device state, programs, prefetch, readback
    /// ring). Policy code goes through the [`Engine`] trait only.
    engine: StepEngine,
    // data
    pub dataset: Dataset,
    // ff machinery
    pub ffc: FfController,
    /// Mean gradient of the last global batch (analysis probes).
    pub last_grads: Vec<Tensor>,
    /// Per-micro-batch gradients of the last global batch (Fig 13).
    pub last_micro_grads: Vec<Vec<Tensor>>,
    /// Keep per-micro grads around (costs memory; off by default). Forces
    /// the host accumulation path — the only remaining consumer of the
    /// host `GradAccumulator` during training.
    pub keep_micro_grads: bool,
    /// Download the mean gradient host-side after each step (Fig 6's
    /// cosine history). FF-tracked steps download it regardless — the FF
    /// stage stats need ‖g‖ — so this flag matters only for FF-off runs,
    /// which otherwise leave [`Trainer::last_grads`] empty.
    pub keep_host_grads: bool,
    // accounting
    pub fm: FlopsModel,
    pub flops: FlopsCounter,
    pub timer: TrainTimer,
    pub log: RunLog,
    /// Cooperative cancellation flag, checked at every step boundary of
    /// [`Trainer::run`] (set by `sched::queue::RunHandle::cancel`).
    cancel: Option<Arc<AtomicBool>>,
    /// Cooperative park flag (preemption): when raised, [`Trainer::run`]
    /// stops at the next *SGD* step boundary with `parked = true` instead
    /// of finishing. Consumed (reset to false) when honored.
    park: Option<Arc<AtomicBool>>,
    /// Fair-share time slice: park after this many Adam steps per
    /// [`Trainer::run`] call (≥ 1 step always executes per slot).
    step_quantum: Option<usize>,
    /// Whether the most recent park was a preemption (flag) rather than a
    /// quantum expiry — the queue uses this to re-enqueue victims at the
    /// front of their priority class.
    preempted: bool,
    /// Transfer totals carried in by [`Trainer::resume_from`]: the parked
    /// run's meter at park time, added on top of this engine's own meter
    /// so [`Trainer::transfers`] reports whole-run traffic exactly.
    carried_transfers: TransferSnapshot,
    /// Dispatched-but-unresolved step records, FIFO by ticket; losses are
    /// backfilled into [`RunLog`] as the engine's readback ring drains.
    pending_records: VecDeque<PendingRecord>,
    /// Mean loss of the most recently resolved step.
    last_loss: Option<f32>,
    /// Initial trainable snapshot (W0 side of Fig 5 / distance probes).
    pub w0_trainables: Vec<Tensor>,
}

impl Trainer {
    /// Build a trainer. `base` optionally carries pretrained weights for
    /// every base parameter (see `pretrain::ensure_pretrained`).
    pub fn new(
        rt: &Arc<Runtime>,
        artifacts_root: &Path,
        cfg: TrainConfig,
        base: Option<&BTreeMap<String, Tensor>>,
    ) -> Result<Trainer> {
        let art = Arc::new(
            Artifact::load(rt, &artifacts_root.join(&cfg.artifact))
                .with_context(|| format!("artifact '{}'", cfg.artifact))?,
        );
        Self::with_artifact(rt, art, cfg, base)
    }

    /// Build a trainer over an already-loaded artifact. Concurrent runs
    /// (`crate::sched`) share one `Arc<Artifact>` per key so compiled
    /// programs are reused read-only across workers.
    pub fn with_artifact(
        rt: &Arc<Runtime>,
        art: Arc<Artifact>,
        cfg: TrainConfig,
        base: Option<&BTreeMap<String, Tensor>>,
    ) -> Result<Trainer> {
        let ac = &art.manifest.config;
        if cfg.global_batch % ac.model.micro_batch != 0 {
            bail!(
                "global batch {} not a multiple of artifact micro batch {}",
                cfg.global_batch,
                ac.model.micro_batch
            );
        }
        let values = match base {
            Some(b) => init_with_base(ac, cfg.seed, b),
            None => init_params(ac, cfg.seed),
        };

        let dataset = make_dataset(
            &cfg.task,
            ac.model.vocab_size,
            ac.model.seq_len,
            cfg.train_examples,
            cfg.test_examples,
            cfg.ff.val_examples,
            cfg.seed,
        )?;
        let pipeline = Pipeline::spawn(
            dataset.train.clone(),
            ac.model.micro_batch,
            cfg.global_batch,
            cfg.seed ^ 0xb47c,
            4,
        );
        let val_batches = eval_batches(&dataset.val, ac.model.eval_batch);
        let test_batches = eval_batches(&dataset.test, ac.model.eval_batch);

        let fm = FlopsModel::for_manifest(&art.manifest);
        let ffc = FfController::new(cfg.ff.clone());
        let mut engine = StepEngine::new(
            rt,
            Arc::clone(&art),
            &values,
            pipeline,
            val_batches,
            test_batches,
        )?;
        // host-fresh at construction: this snapshot downloads nothing
        let w0_trainables = engine.trainable_snapshot()?;

        Ok(Trainer {
            cfg,
            art,
            engine,
            dataset,
            ffc,
            last_grads: Vec::new(),
            last_micro_grads: Vec::new(),
            keep_micro_grads: false,
            keep_host_grads: false,
            fm,
            flops: FlopsCounter::default(),
            timer: TrainTimer::start(),
            log: RunLog::default(),
            cancel: None,
            park: None,
            step_quantum: None,
            preempted: false,
            carried_transfers: TransferSnapshot::default(),
            pending_records: VecDeque::new(),
            last_loss: None,
            w0_trainables,
        })
    }

    pub fn adam_steps(&self) -> usize {
        self.engine.adam_steps()
    }

    /// Install a cooperative cancellation flag. [`Trainer::run`] checks it
    /// at every step boundary (before dispatching the next SGD step or FF
    /// stage): once set, the loop stops, the pipeline drains, the final
    /// eval runs, and the summary comes back with `cancelled = true` —
    /// cancellation is a clean early stop, never an error or a torn state.
    pub fn set_cancel_flag(&mut self, flag: Arc<AtomicBool>) {
        self.cancel = Some(flag);
    }

    /// Whether the installed cancel flag (if any) has been raised.
    pub fn cancel_requested(&self) -> bool {
        self.cancel.as_ref().is_some_and(|f| f.load(Ordering::SeqCst))
    }

    /// Install a cooperative park flag. Once raised, [`Trainer::run`]
    /// stops at the next **SGD** step boundary (a due FF stage runs
    /// first, so the controller position never parks mid-stage) with
    /// `parked = true`; the flag is consumed so the next slot starts
    /// clean. Cancellation wins over parking when both are raised.
    pub fn set_park_flag(&mut self, flag: Arc<AtomicBool>) {
        self.park = Some(flag);
    }

    /// Install a fair-share step quantum: [`Trainer::run`] parks after
    /// `quantum.max(1)` Adam steps per call, letting a queue time-slice
    /// same-priority runs. Progress is guaranteed: at least one step
    /// executes per slot.
    pub fn set_step_quantum(&mut self, quantum: usize) {
        self.step_quantum = Some(quantum);
    }

    /// Whether the most recent parked stop was a preemption (park flag)
    /// rather than a quantum expiry.
    pub fn park_was_preemption(&self) -> bool {
        self.preempted
    }

    /// Whether a park is due at this SGD step boundary. Consumes a raised
    /// park flag; otherwise checks the step quantum against the steps
    /// taken since this `run` slot began.
    fn park_due(&mut self, slot_start: usize) -> bool {
        if let Some(flag) = &self.park {
            if flag.swap(false, Ordering::SeqCst) {
                self.preempted = true;
                return true;
            }
        }
        self.step_quantum
            .is_some_and(|q| self.adam_steps().saturating_sub(slot_start) >= q.max(1))
    }

    /// Monotone step index counting SGD + simulated steps (Fig 4 x-axis).
    pub fn total_steps(&self) -> usize {
        self.engine.adam_steps() + self.log.n_ff()
    }

    /// Host↔device traffic attributable to this trainer so far. For a
    /// resumed trainer this includes the parked run's carried totals, so
    /// the number always reads whole-run traffic — park-sync downloads
    /// and resume re-uploads included, exactly once each.
    pub fn transfers(&self) -> TransferSnapshot {
        self.carried_transfers.plus(&self.engine.transfers())
    }

    /// (uploads, downloads) summed over the trainable/m/v ParamSets. With
    /// device-resident, donated state the upload count goes flat after the
    /// first Adam step; downloads grow by |trainable| per step only while
    /// FF tracks Δ_W, and not at all on baseline runs (see
    /// docs/transfer-contract.md §3).
    pub fn state_transfer_counts(&self) -> (u64, u64) {
        self.engine.state_transfer_counts()
    }

    /// Number of trainable tensors (sync-free).
    pub fn trainable_count(&self) -> usize {
        self.engine.trainable_count()
    }

    /// Total trainable elements (sync-free).
    pub fn trainable_numel(&self) -> usize {
        self.engine.trainable_numel()
    }

    /// Number of frozen tensors (sync-free; resume byte accounting).
    pub fn frozen_count(&self) -> usize {
        self.engine.frozen_count()
    }

    /// Total frozen elements (sync-free; resume byte accounting).
    pub fn frozen_numel(&self) -> usize {
        self.engine.frozen_numel()
    }

    /// Trainable tensor shapes without any device→host sync — the right
    /// call when only the geometry is needed (probe directions, logging).
    pub fn trainable_shapes(&self) -> Vec<Vec<usize>> {
        self.engine.trainable_shapes()
    }

    /// Deferred-readback ring counters (drains by reason, max depth).
    pub fn stream_stats(&self) -> &StreamStats {
        self.engine.stream_stats()
    }

    /// Steps dispatched but not yet resolved.
    pub fn pending_steps(&self) -> usize {
        self.engine.pending_depth()
    }

    /// Set the readback ring's drain interval (1 = fully synchronous; the
    /// default is `engine::DEFAULT_DRAIN_INTERVAL`).
    pub fn set_drain_interval(&mut self, k: usize) {
        self.engine.set_drain_interval(k);
    }

    // ---------------------------------------------------------------------
    // Core steps
    // ---------------------------------------------------------------------

    /// One Adam optimizer step, synchronously: dispatch through the engine
    /// then drain the readback ring and return this step's mean
    /// micro-batch loss. Equivalent to the pipelined path with a drain
    /// interval of 1 — `deferred_readback_matches_synchronous_losses`
    /// (trainer_e2e) holds the two bit-for-bit equal.
    pub fn sgd_step(&mut self) -> Result<f32> {
        self.dispatch_sgd_step()?;
        self.drain_pending(SyncReason::StepResult)?;
        self.last_loss
            .ok_or_else(|| anyhow!("step dispatched but no loss resolved"))
    }

    /// The pipelined half: dispatch one Adam step and return without
    /// waiting for its loss. The step's record enters a pending FIFO and
    /// its loss is backfilled into the log when the engine's ring drains —
    /// every K steps, or at the next boundary ([`Trainer::drain_pending`],
    /// eval, FF stage, end of run).
    pub fn dispatch_sgd_step(&mut self) -> Result<()> {
        // Δ_W is only consumed by FF (ff_stage / ff_probe_fixed). Baseline
        // runs — and tail steps after the convergence rule permanently
        // disables FF — skip the tracking, so their steady-state steps
        // move *zero* parameter/optimizer bytes in either direction.
        let track_delta = self.cfg.ff.enabled && !self.ffc.is_permanently_off();
        let opts = StepOptions {
            lr: self.cfg.lr,
            track_delta,
            keep_micro_grads: self.keep_micro_grads,
            keep_host_grads: self.keep_host_grads,
        };
        let d = self.engine.dispatch_step(&opts)?;
        self.last_grads = d.mean_grads;
        self.last_micro_grads = d.micro_grads;
        self.ffc.on_sgd_step();
        self.flops.sgd_step(&self.fm, d.tokens);
        self.pending_records.push_back(PendingRecord {
            ticket: d.ticket,
            step: self.total_steps(),
            flops: self.flops.total(),
            seconds: self.timer.elapsed(),
        });
        self.absorb_resolved(d.resolved)?;
        Ok(())
    }

    /// Force the engine to retire every in-flight step and backfill the
    /// run log. No-op when nothing is pending.
    ///
    /// Invariant (hard error, not debug-only): a forced sync drains the
    /// whole readback ring, so afterwards **no** step record may still be
    /// pending — a partial drain would silently drop run-log losses in
    /// release builds, corrupting every loss-curve figure downstream.
    pub fn drain_pending(&mut self, reason: SyncReason) -> Result<()> {
        let resolved = self.engine.sync(reason)?;
        self.absorb_resolved(resolved)?;
        ensure!(
            self.pending_records.is_empty(),
            "forced '{}' drain left {} dispatched step record(s) unresolved \
             — their run-log losses would be dropped",
            reason.as_str(),
            self.pending_records.len()
        );
        Ok(())
    }

    /// Match resolved steps (FIFO by ticket) to their pending records and
    /// write the completed [`StepRecord`]s. Mismatches are hard errors:
    /// the log must never silently lose or reorder a dispatched step.
    fn absorb_resolved(&mut self, resolved: Vec<ResolvedStep>) -> Result<()> {
        for r in resolved {
            let rec = self
                .pending_records
                .pop_front()
                .ok_or_else(|| anyhow!("resolved step {} without a pending record", r.ticket))?;
            ensure!(
                rec.ticket == r.ticket,
                "deferred readback out of order: resolved ticket {} but the \
                 oldest pending record is {}",
                r.ticket,
                rec.ticket
            );
            self.log.push(StepRecord {
                step: rec.step,
                kind: StepKind::Sgd,
                loss: r.mean_loss,
                flops: rec.flops,
                seconds: rec.seconds,
            });
            self.last_loss = Some(r.mean_loss);
        }
        Ok(())
    }

    /// Tiny-validation-set loss (charged as FF inference per the paper).
    /// An eval is a pipeline boundary: pending steps retire first.
    pub fn eval_val(&mut self) -> Result<f32> {
        self.drain_pending(SyncReason::Eval)?;
        let m = self.engine.eval_split(EvalSplit::Val)?;
        self.flops.ff_probe(&self.fm, m.tokens);
        Ok(m.loss)
    }

    /// Held-out test loss (measurement only: excluded from train time and
    /// chargeable FLOPs).
    pub fn eval_test(&mut self) -> Result<f32> {
        self.drain_pending(SyncReason::Eval)?;
        self.timer.pause();
        let r = self.engine.eval_split(EvalSplit::Test);
        self.timer.resume();
        let m = r?;
        self.flops.test_eval(&self.fm, m.tokens);
        let (s, f, t) = (self.total_steps(), self.flops.total(), self.timer.elapsed());
        self.log.test_evals.push((m.loss, s, f, t));
        Ok(m.loss)
    }

    /// Run one Fast Forward stage (paper §3): line search along the most
    /// recent Δ_W, stopping when tiny-val loss stops improving. A stage is
    /// a hard pipeline boundary — every dispatched step retires first.
    pub fn ff_stage(&mut self) -> Result<FfStageStats> {
        self.drain_pending(SyncReason::FfBoundary)?;
        let delta = match self.engine.delta() {
            Some(d) => d.to_vec(),
            None if !self.cfg.ff.enabled => bail!(
                "ff_stage on an FF-disabled trainer: Δ_W tracking is gated \
                 on cfg.ff.enabled (baseline steps stay device-resident)"
            ),
            None if self.ffc.is_permanently_off() => bail!(
                "ff_stage after the convergence rule permanently disabled FF"
            ),
            None => bail!("ff_stage before any optimizer step"),
        };
        let grad_norm = list_norm(&self.last_grads);
        let grad_cond = mean_condition_number(&self.last_grads);
        let baseline = self.eval_val()?;

        let max_tau = self.cfg.ff.max_tau;
        let min_rel = self.cfg.ff.min_rel_improvement;
        let result = {
            let mut target = TrainerSearchTarget { trainer: self, delta: &delta };
            line_search_thresholded(&mut target, baseline, max_tau, min_rel)?
        };
        let stats = self.record_ff(&result, grad_norm, grad_cond)?;
        // LoFT-style backend: the stage just jumped the weights far along
        // Δ_W, so the Adam moments describe pre-jump curvature. Decay them
        // (m *= d, v *= d²) so the next steps are not mis-scaled by stale
        // second-moment estimates. The realign's FLOPs (2·|trainables|
        // multiplies) are charged as FF parameter updates.
        if self.cfg.backend == OptimBackend::Loft {
            self.engine.loft_realign(self.cfg.loft_decay)?;
            self.flops.ff_param_updates += 2 * self.trainable_numel() as u64;
        }
        Ok(stats)
    }

    /// Fig 10 probe: run exactly `n_steps` simulated steps with *no* stop
    /// rule, recording val loss at each τ, then restore W_t.
    pub fn ff_probe_fixed(&mut self, n_steps: usize) -> Result<Vec<f32>> {
        self.drain_pending(SyncReason::FfBoundary)?;
        let delta = match self.engine.delta() {
            Some(d) => d.to_vec(),
            None if !self.cfg.ff.enabled => bail!(
                "ff_probe on an FF-disabled trainer: Δ_W tracking is gated \
                 on cfg.ff.enabled (baseline steps stay device-resident)"
            ),
            None if self.ffc.is_permanently_off() => bail!(
                "ff_probe after the convergence rule permanently disabled FF"
            ),
            None => bail!("ff_probe before any optimizer step"),
        };
        let snap = self.engine.trainable_snapshot()?;
        let mut losses = Vec::with_capacity(n_steps + 1);
        losses.push(self.eval_val()?);
        for _ in 0..n_steps {
            self.engine.axpy_trainables(1.0, &delta)?;
            losses.push(self.eval_val()?);
        }
        self.engine.restore_trainables(&snap);
        Ok(losses)
    }

    /// Feed the active FF policy whichever signals it requested after an
    /// SGD step. The default `IntervalPolicy` requests nothing, so this
    /// is a no-op on the default path — zero extra evals, zero extra
    /// transfers — which is what keeps the default run loop bit-identical
    /// to the pre-policy controller. Signal-hungry policies run on the
    /// synchronous path: observing Δ_W or a tiny-val loss forces a drain
    /// at each step boundary (the val eval is charged as FF-probe FLOPs,
    /// exactly like a line-search probe).
    fn observe_policy_signals(&mut self) -> Result<()> {
        if self.ffc.wants_delta() {
            self.drain_pending(SyncReason::StepResult)?;
            if let Some(d) = self.engine.delta() {
                let d = d.to_vec();
                self.ffc.observe_delta(&d);
            }
        }
        if self.ffc.wants_val_loss() {
            let loss = self.eval_val()?;
            self.ffc.observe_val_loss(loss);
        }
        Ok(())
    }

    fn record_ff(
        &mut self,
        r: &LineSearchResult,
        grad_norm: f64,
        grad_cond: f64,
    ) -> Result<FfStageStats> {
        // Each kept simulated step is a step record (Fig 4 green dots).
        for loss in r.losses.iter().take(r.tau_star) {
            self.log.push(StepRecord {
                step: self.total_steps() + 1,
                kind: StepKind::FastForward,
                loss: *loss,
                flops: self.flops.total(),
                seconds: self.timer.elapsed(),
            });
        }
        let stats = FfStageStats {
            stage: self.ffc.n_stages(),
            at_step: self.adam_steps(),
            tau_star: r.tau_star,
            probes: r.probes,
            baseline_loss: r.baseline_loss,
            final_loss: r.final_loss,
            grad_norm,
            grad_cond,
        };
        self.ffc.on_ff_stage(stats.clone());
        crate::debug!(
            "FF stage {}: τ*={} probes={} val {:.4}→{:.4}",
            stats.stage,
            stats.tau_star,
            stats.probes,
            stats.baseline_loss,
            stats.final_loss
        );
        Ok(stats)
    }

    // ---------------------------------------------------------------------
    // Run loops
    // ---------------------------------------------------------------------

    /// Drive the controller until the stop rule fires; returns the summary.
    ///
    /// SGD steps go through the **pipelined** dispatch path: up to the
    /// engine's drain interval of steps stay in flight, and the readback
    /// ring drains at FF stages, evals, and the end of the run (the log
    /// comes out identical to the synchronous path, just written later).
    pub fn run(&mut self, stop: &StopRule) -> Result<RunSummary> {
        let mut reached = false;
        // True only when the *loop* stopped because of the flag — a
        // cancel that lands after the stop rule already ended the run
        // (e.g. during the final drain/eval) cut no work short and must
        // not mark a fully-delivered run cancelled.
        let mut cancelled = false;
        let mut parked = false;
        self.preempted = false;
        // Steps already taken when this slot began — the quantum counts
        // per `run` call, so a resumed run gets a full fresh slice.
        let slot_start = self.adam_steps();
        loop {
            let max = match stop {
                StopRule::MaxSteps(n) => *n,
                StopRule::TargetLoss { max_steps, .. } => *max_steps,
                StopRule::Convergence { max_steps, .. } => *max_steps,
            };
            // Step-budget exhaustion is checked FIRST: a cancel that
            // races a run's natural completion must not reclassify a
            // fully-delivered run as cancelled.
            if self.adam_steps() >= max {
                break;
            }
            // Cooperative cancellation lands here — a step boundary: the
            // previous step/stage fully dispatched, nothing half-done,
            // and at least one more step was still owed. Cancel beats
            // park: a cancelled run must not re-enter the queue.
            if self.cancel_requested() {
                cancelled = true;
                break;
            }
            let decision = self.ffc.next();
            // Parking lands only on an SGD boundary: a *due* FF stage
            // runs first and the park waits one boundary. This keeps
            // resume bit-identical — the controller position in a park
            // state never sits on a half-owed stage whose Δ_W (device
            // state from the preceding step) could not be snapshotted.
            if decision == FfDecision::Sgd && self.park_due(slot_start) {
                parked = true;
                break;
            }
            let did_ff = match decision {
                FfDecision::Sgd => {
                    self.dispatch_sgd_step()?;
                    self.observe_policy_signals()?;
                    false
                }
                FfDecision::FastForward => {
                    self.ff_stage()?;
                    true
                }
            };
            if let StopRule::TargetLoss { target, eps, eval_every, .. } = stop {
                // Check after every FF stage (a single stage can jump far
                // past the target) and on the SGD cadence otherwise.
                if did_ff || self.adam_steps() % eval_every == 0 {
                    let test = self.eval_test()?;
                    if test <= *target + *eps {
                        reached = true;
                        break;
                    }
                }
            }
            if let StopRule::Convergence { tail, .. } = stop {
                if self.ffc.is_permanently_off() {
                    for _ in 0..*tail {
                        if self.cancel_requested() {
                            cancelled = true;
                            break;
                        }
                        self.dispatch_sgd_step()?;
                    }
                    break;
                }
            }
        }
        self.drain_pending(SyncReason::Shutdown)?;
        // A parked run skips the final eval: it hasn't finished — the
        // resumed run will evaluate once, at its true end. (Skipping also
        // keeps the test-eval cache off parked slots, so a park/resume
        // cycle's transfer overhead stays exactly the state bytes.)
        let final_test_loss = if parked { f32::NAN } else { self.eval_test()? };
        Ok(RunSummary {
            final_test_loss,
            adam_steps: self.adam_steps(),
            sim_steps: self.log.n_ff(),
            flops: self.flops,
            train_seconds: self.timer.elapsed(),
            reached_target: reached,
            cancelled,
            parked,
            transfers: self.transfers(),
            store: None,
        })
    }

    // ---------------------------------------------------------------------
    // Park / resume (queue preemption — docs/queue-serving.md)
    // ---------------------------------------------------------------------

    /// Snapshot a parked run into a [`ParkState`]: full optimizer state
    /// (trainables + Adam moments), the step/FF-controller position, the
    /// run log so far, and the exact accounting (FLOPs, train seconds,
    /// transfer meter). The meter is read *after* the state downloads, so
    /// the park sync itself is billed to the parked side — a later
    /// resumed summary reports whole-run traffic with nothing counted
    /// twice or dropped.
    pub fn park_state(&mut self) -> Result<ParkState> {
        self.drain_pending(SyncReason::Snapshot)?;
        let (trainables, m, v) = self.engine.state_snapshot()?;
        Ok(ParkState {
            trainables,
            m,
            v,
            adam_steps: self.adam_steps(),
            ff: self.ffc.position(),
            ff_aux: self.ffc.aux_state(),
            ff_fingerprint: self.cfg.ff.fingerprint(),
            stages: self.ffc.stages.clone(),
            records: self.log.records.clone(),
            test_evals: self.log.test_evals.clone(),
            flops: self.flops,
            train_seconds: self.timer.elapsed(),
            transfers: self.transfers(),
        })
    }

    /// Resume a parked run on a freshly constructed trainer (same
    /// artifact, same `TrainConfig` — in particular the same seed, so the
    /// deterministic data pipeline and `w0_trainables` reproduce the
    /// original run's). Restores optimizer state and the Adam step
    /// counter, fast-forwards the data stream past the consumed batches,
    /// restores the FF-controller position, and carries the run log,
    /// FLOPs, train seconds, and transfer totals — after this,
    /// `run(&same_stop_rule)` continues bit-identically to a run that was
    /// never parked.
    pub fn resume_from(&mut self, park: &ParkState) -> Result<()> {
        ensure!(
            self.adam_steps() == 0 && self.log.records.is_empty(),
            "resume_from requires a freshly constructed trainer \
             ({} steps already taken)",
            self.adam_steps()
        );
        let shapes = self.engine.trainable_shapes();
        ensure!(
            park.trainables.len() == shapes.len(),
            "park state has {} trainables but artifact '{}' has {}",
            park.trainables.len(),
            self.cfg.artifact,
            shapes.len()
        );
        for (i, t) in park.trainables.iter().enumerate() {
            ensure!(
                t.shape == shapes[i],
                "park state trainable {i} has shape {:?} but artifact '{}' expects {:?}",
                t.shape,
                self.cfg.artifact,
                shapes[i]
            );
        }
        // A snapshot is only meaningful under the FfConfig it was taken
        // with: an edited config (different policy, interval bounds,
        // thresholds…) would silently run with stale scheduling state.
        // Legacy park files (empty fingerprint) skip the check; the
        // policy-kind tag on the position still guards the worst case.
        ensure!(
            park.ff_fingerprint.is_empty() || park.ff_fingerprint == self.cfg.ff.fingerprint(),
            "park state was taken under a different FfConfig \
             (snapshot '{}' vs current '{}') — refusing to resume; \
             re-submit with the original config",
            park.ff_fingerprint,
            self.cfg.ff.fingerprint()
        );
        self.engine.restore_state(&park.trainables, &park.m, &park.v, park.adam_steps);
        // The pipeline replays deterministically from the seed: discard
        // the batches the parked run already consumed (one per Adam step).
        self.engine.skip_batches(park.adam_steps)?;
        self.ffc.restore_position(&park.ff)?;
        self.ffc.restore_aux(&park.ff_aux)?;
        self.ffc.stages = park.stages.clone();
        self.flops = park.flops;
        for r in &park.records {
            self.log.push(r.clone());
        }
        self.log.test_evals = park.test_evals.clone();
        self.last_loss = self.log.last_loss();
        self.timer.credit(park.train_seconds);
        self.carried_transfers = park.transfers;
        Ok(())
    }

    // ---------------------------------------------------------------------
    // Analysis hooks
    // ---------------------------------------------------------------------

    /// Evaluate test loss at arbitrary trainable values (Fig 5 plane scan);
    /// restores the current trainables afterwards.
    pub fn eval_test_at(&mut self, trainables: &[Tensor]) -> Result<f32> {
        self.drain_pending(SyncReason::Eval)?;
        let snap = self.engine.trainable_snapshot()?;
        self.engine.restore_trainables(trainables);
        let r = self.engine.eval_split(EvalSplit::Test);
        self.engine.restore_trainables(&snap);
        let m = r?;
        self.flops.test_eval(&self.fm, m.tokens);
        Ok(m.loss)
    }

    /// Loss of one example through the eval program (QA scoring). The
    /// example is padded to the eval batch shape with zero-mask rows and
    /// staged through a per-engine scratch, so scoring a benchmark
    /// allocates nothing per example.
    pub fn eval_example_loss(&mut self, ex: &crate::data::corpus::Example) -> Result<f32> {
        self.drain_pending(SyncReason::Eval)?;
        let m = self.engine.eval_example(ex)?;
        self.flops.test_eval(&self.fm, m.tokens);
        Ok(m.loss)
    }

    /// Current trainable snapshot (W_t), syncing any device-ahead state
    /// first — the one download a baseline run ever pays for its params.
    /// Callers that only need shapes should use
    /// [`Trainer::trainable_shapes`] (sync-free) instead.
    pub fn trainables(&mut self) -> Result<Vec<Tensor>> {
        self.drain_pending(SyncReason::Snapshot)?;
        self.engine.trainable_snapshot()
    }

    /// Apply `W += alpha·delta` on the live trainables (bench/probe hook —
    /// the same host axpy a FF simulated step performs).
    pub fn tr_axpy_for_bench(&mut self, delta: &[Tensor], alpha: f32) -> Result<()> {
        self.engine.axpy_trainables(alpha, delta)
    }

    /// All current parameters by name (checkpointing). Downloads lazily —
    /// only device-ahead trainables; frozen params are never
    /// device-written.
    pub fn all_params(&mut self) -> Result<BTreeMap<String, Tensor>> {
        self.drain_pending(SyncReason::Snapshot)?;
        self.engine.named_params()
    }
}

/// Line-search target over the live trainer (paper Eq. 2 applied to the
/// real ParamSet through the engine's axpy/eval path).
struct TrainerSearchTarget<'a> {
    trainer: &'a mut Trainer,
    delta: &'a [Tensor],
}

impl SearchTarget for TrainerSearchTarget<'_> {
    fn begin(&mut self) -> Result<()> {
        // A line search is a pipeline boundary: every dispatched step must
        // retire before W starts moving host-side.
        self.trainer.drain_pending(SyncReason::FfBoundary)
    }

    fn apply(&mut self) -> Result<()> {
        self.trainer.engine.axpy_trainables(1.0, self.delta)
    }

    fn revert(&mut self) -> Result<()> {
        self.trainer.engine.axpy_trainables(-1.0, self.delta)
    }

    fn eval(&mut self) -> Result<f32> {
        self.trainer.eval_val()
    }
}
