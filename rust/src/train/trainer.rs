//! The training coordinator: wires the data pipeline, PJRT runtime,
//! device-side micro-batch gradient accumulation, Adam, and the Fast
//! Forward controller into the paper's training protocol.
//!
//! One `Trainer` = one run (one artifact, one task, one FfConfig). The
//! experiment harnesses construct pairs of trainers (baseline vs FF) over
//! identical data and compare FLOPs/time to matched test loss.
//!
//! # Data flow: device buffers are the source of truth
//!
//! During training the authoritative parameter/optimizer state lives on
//! the device, and so does the gradient pipeline between micro-batches:
//!
//! * **Accumulation** — each micro-batch's `grad_step` runs in raw mode;
//!   only its loss scalar (4 bytes) is downloaded. The gradient buffers
//!   fold into a [`DeviceGradAccumulator`] (`grad_accum` / `grad_finalize`
//!   AOT programs, donated in place), so per-micro gradients never visit
//!   the host and the mean gradient is never uploaded. The host
//!   [`GradAccumulator`] path survives behind
//!   [`Trainer::keep_micro_grads`] (Fig 13 needs every micro gradient
//!   host-side) and for artifacts that predate the accumulation programs.
//! * **Adam** — the accumulated mean-gradient buffers feed straight into
//!   `adam_apply` together with the trainable/m/v state, all **donated**
//!   (`ParamSet::take_device_buffers` → `Program::execute_raw_donated`):
//!   PJRT reuses the input allocations for the aliased outputs, keeping
//!   one generation of state live per step instead of two. The outputs
//!   are adopted straight back (`ParamSet::adopt_all`) — trainable, m,
//!   and v are **never re-uploaded** in steady state, and m/v are never
//!   downloaded at all.
//! * **Host syncs** — lazy. The only per-step download beyond loss
//!   scalars is the trainable set (Δ_W = W_t − W_{t−1}, `DeltaTracker`)
//!   plus, when FF or an analysis consumer needs it, the mean gradient
//!   ([`Trainer::keep_host_grads`]). Baseline (FF-off) runs move zero
//!   state or gradient bytes in either direction: their steady-state
//!   uploads are batch tokens/targets/mask and two 4-byte scalars.
//! * **Eval** — batches upload once into an `EvalCache` and are reused by
//!   every FF probe and test eval.
//!
//! All traffic is metered in `Runtime::stats` and surfaced per run in
//! `RunSummary::transfers`; `docs/transfer-contract.md` spells out the
//! full contract and the steady-state expectations `bench_step` verifies.

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::analysis::linalg::mean_condition_number;
use crate::config::TrainConfig;
use crate::data::batcher::{eval_batches, Batch, GlobalBatch};
use crate::data::corpus::{make_dataset, Dataset};
use crate::data::pipeline::Pipeline;
use crate::ff::controller::{FfController, FfDecision, FfStageStats};
use crate::ff::line_search::{line_search_thresholded, LineSearchResult, SearchTarget};
use crate::flops::{FlopsCounter, FlopsModel};
use crate::metrics::{RunLog, StepKind, StepRecord, TrainTimer};
use crate::model::init::{init_params, init_with_base};
use crate::model::tensor::{list_norm, Tensor};
use crate::optim::accum::{DeviceGradAccumulator, GradAccumulator};
use crate::optim::delta::DeltaTracker;
use crate::runtime::{Artifact, InputBuf, ParamSet, Program, Runtime, TransferSnapshot};
use crate::train::eval_cache::{EvalCache, ExampleScratch};

/// When to stop a training run.
#[derive(Debug, Clone)]
pub enum StopRule {
    /// Fixed number of Adam steps (the 5-epoch baseline protocol).
    MaxSteps(usize),
    /// Stop once test loss ≤ target + eps, checking every `eval_every`
    /// Adam steps (the FF run's "match the baseline" protocol, §4).
    TargetLoss { target: f32, eps: f32, eval_every: usize, max_steps: usize },
    /// Run until the controller turns FF off permanently (§5.1), then a
    /// final `tail` SGD steps.
    Convergence { max_steps: usize, tail: usize },
}

#[derive(Debug, Clone)]
pub struct RunSummary {
    pub final_test_loss: f32,
    pub adam_steps: usize,
    pub sim_steps: usize,
    pub flops: FlopsCounter,
    pub train_seconds: f64,
    pub reached_target: bool,
    /// Host↔device traffic attributable to this trainer since construction
    /// (uploads/downloads, calls and bytes) — see runtime §Perf counters.
    pub transfers: TransferSnapshot,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub art: Rc<Artifact>,
    rt: Rc<Runtime>,
    // parameter state
    pub tr: ParamSet,
    pub fr: ParamSet,
    m: ParamSet,
    v: ParamSet,
    adam_steps: usize,
    // data
    pub dataset: Dataset,
    pipeline: Pipeline,
    val_batches: Vec<(Batch, usize)>,
    test_batches: Vec<(Batch, usize)>,
    // device-resident eval inputs (built lazily on first eval of a split)
    val_cache: Option<EvalCache>,
    test_cache: Option<EvalCache>,
    qa_scratch: Option<ExampleScratch>,
    // programs
    grad_prog: Rc<Program>,
    adam_prog: Rc<Program>,
    eval_prog: Rc<Program>,
    /// Device-side accumulation programs (`grad_accum`/`grad_finalize`).
    /// `None` for artifacts emitted before they existed — the trainer then
    /// falls back to the host [`GradAccumulator`] path.
    grad_accum_prog: Option<Rc<Program>>,
    grad_finalize_prog: Option<Rc<Program>>,
    /// Cached learning-rate scalar buffer, keyed by the lr value it holds
    /// so mid-run mutation of `cfg.lr` (lr sweeps) re-uploads.
    lr_buf: Option<(f32, xla::PjRtBuffer)>,
    /// Cached `1/n_micro` scalar for `grad_finalize`, keyed by the micro
    /// count it encodes (constant per run: global_batch / micro_batch).
    inv_n_buf: Option<(usize, xla::PjRtBuffer)>,
    // ff machinery
    pub ffc: FfController,
    delta: DeltaTracker,
    /// Mean gradient of the last global batch (analysis probes).
    pub last_grads: Vec<Tensor>,
    /// Per-micro-batch gradients of the last global batch (Fig 13).
    pub last_micro_grads: Vec<Vec<Tensor>>,
    /// Keep per-micro grads around (costs memory; off by default). Forces
    /// the host accumulation path — the only remaining consumer of the
    /// host [`GradAccumulator`] during training.
    pub keep_micro_grads: bool,
    /// Download the mean gradient host-side after each step (Fig 6's
    /// cosine history). FF-tracked steps download it regardless — the FF
    /// stage stats need ‖g‖ — so this flag matters only for FF-off runs,
    /// which otherwise leave [`Trainer::last_grads`] empty.
    pub keep_host_grads: bool,
    // accounting
    pub fm: FlopsModel,
    pub flops: FlopsCounter,
    pub timer: TrainTimer,
    pub log: RunLog,
    transfers_at_start: TransferSnapshot,
    /// Initial trainable snapshot (W0 side of Fig 5 / distance probes).
    pub w0_trainables: Vec<Tensor>,
}

impl Trainer {
    /// Build a trainer. `base` optionally carries pretrained weights for
    /// every base parameter (see `pretrain::ensure_pretrained`).
    pub fn new(
        rt: &Rc<Runtime>,
        artifacts_root: &Path,
        cfg: TrainConfig,
        base: Option<&BTreeMap<String, Tensor>>,
    ) -> Result<Trainer> {
        let art = Rc::new(
            Artifact::load(rt, &artifacts_root.join(&cfg.artifact))
                .with_context(|| format!("artifact '{}'", cfg.artifact))?,
        );
        Self::with_artifact(rt, art, cfg, base)
    }

    pub fn with_artifact(
        rt: &Rc<Runtime>,
        art: Rc<Artifact>,
        cfg: TrainConfig,
        base: Option<&BTreeMap<String, Tensor>>,
    ) -> Result<Trainer> {
        let man = &art.manifest;
        let ac = &man.config;
        if cfg.global_batch % ac.model.micro_batch != 0 {
            bail!(
                "global batch {} not a multiple of artifact micro batch {}",
                cfg.global_batch,
                ac.model.micro_batch
            );
        }
        let values = match base {
            Some(b) => init_with_base(ac, cfg.seed, b),
            None => init_params(ac, cfg.seed),
        };
        let tr = ParamSet::from_spec(rt, &man.trainable, &values)?;
        let fr = ParamSet::from_spec(rt, &man.frozen, &values)?;
        let m = ParamSet::zeros_like(rt, &tr);
        let v = ParamSet::zeros_like(rt, &tr);

        let dataset = make_dataset(
            &cfg.task,
            ac.model.vocab_size,
            ac.model.seq_len,
            cfg.train_examples,
            cfg.test_examples,
            cfg.ff.val_examples,
            cfg.seed,
        )?;
        let pipeline = Pipeline::spawn(
            dataset.train.clone(),
            ac.model.micro_batch,
            cfg.global_batch,
            cfg.seed ^ 0xb47c,
            4,
        );
        let val_batches = eval_batches(&dataset.val, ac.model.eval_batch);
        let test_batches = eval_batches(&dataset.test, ac.model.eval_batch);

        let grad_prog = art.program("grad_step")?;
        let adam_prog = art.program("adam_apply")?;
        let eval_prog = art.program("eval_loss")?;
        // Optional device-side accumulation pair (see sgd_step): both or
        // neither — a manifest with only one of them is malformed enough
        // to fall back to the host path rather than half-commit.
        let (grad_accum_prog, grad_finalize_prog) =
            if man.has_program("grad_accum") && man.has_program("grad_finalize") {
                (Some(art.program("grad_accum")?), Some(art.program("grad_finalize")?))
            } else {
                (None, None)
            };
        let fm = FlopsModel::for_artifact(ac);
        let ffc = FfController::new(cfg.ff.clone());
        let w0_trainables = tr.snapshot();
        let transfers_at_start = rt.stats.snapshot();

        Ok(Trainer {
            cfg,
            rt: Rc::clone(rt),
            art,
            tr,
            fr,
            m,
            v,
            adam_steps: 0,
            dataset,
            pipeline,
            val_batches,
            test_batches,
            val_cache: None,
            test_cache: None,
            qa_scratch: None,
            grad_prog,
            adam_prog,
            eval_prog,
            grad_accum_prog,
            grad_finalize_prog,
            lr_buf: None,
            inv_n_buf: None,
            ffc,
            delta: DeltaTracker::new(),
            last_grads: Vec::new(),
            last_micro_grads: Vec::new(),
            keep_micro_grads: false,
            keep_host_grads: false,
            fm,
            flops: FlopsCounter::default(),
            timer: TrainTimer::start(),
            log: RunLog::default(),
            transfers_at_start,
            w0_trainables,
        })
    }

    pub fn adam_steps(&self) -> usize {
        self.adam_steps
    }

    /// Monotone step index counting SGD + simulated steps (Fig 4 x-axis).
    pub fn total_steps(&self) -> usize {
        self.adam_steps + self.log.n_ff()
    }

    /// Host↔device traffic attributable to this trainer so far.
    pub fn transfers(&self) -> TransferSnapshot {
        self.rt.stats.snapshot().since(&self.transfers_at_start)
    }

    /// (uploads, downloads) summed over the trainable/m/v ParamSets. With
    /// device-resident, donated state the upload count goes flat after the
    /// first Adam step; downloads grow by |trainable| per step only while
    /// FF tracks Δ_W, and not at all on baseline runs (see
    /// docs/transfer-contract.md §3).
    pub fn state_transfer_counts(&self) -> (u64, u64) {
        (
            self.tr.upload_count() + self.m.upload_count() + self.v.upload_count(),
            self.tr.download_count() + self.m.download_count() + self.v.download_count(),
        )
    }

    // ---------------------------------------------------------------------
    // Core steps
    // ---------------------------------------------------------------------

    /// One Adam optimizer step over a full global batch: micro-batch
    /// gradient accumulation **on the device** (`grad_accum` /
    /// `grad_finalize`, see module docs) → one donated `adam_apply`, whose
    /// outputs stay on the device as the next step's inputs. Per-micro
    /// gradients never visit the host unless [`Trainer::keep_micro_grads`]
    /// forces the reference host path.
    pub fn sgd_step(&mut self) -> Result<f32> {
        let global = self.pipeline.next();
        // Δ_W is only consumed by FF (ff_stage / ff_probe_fixed). Baseline
        // runs — and tail steps after the convergence rule permanently
        // disables FF — skip the tracking, so their steady-state steps
        // move *zero* parameter/optimizer bytes in either direction.
        let track_delta = self.cfg.ff.enabled && !self.ffc.is_permanently_off();
        let use_device_accum =
            self.grad_accum_prog.is_some() && !self.keep_micro_grads;
        let (g_bufs, mean_loss) = if use_device_accum {
            // micro grads stay on the device — don't leave a previous
            // keep_micro_grads run's tensors looking current
            self.last_micro_grads.clear();
            let (bufs, loss) = self.accumulate_device(&global)?;
            // ff_stage stats need ‖g‖ host-side; Fig 6 asks via
            // keep_host_grads. Everyone else skips the download and
            // last_grads stays empty.
            if track_delta || self.keep_host_grads {
                self.last_grads = self.download_grads(&bufs)?;
            } else {
                self.last_grads.clear();
            }
            (bufs, loss)
        } else {
            let (mean_grads, loss) = self.accumulate_host(&global)?;
            let bufs: Vec<xla::PjRtBuffer> = mean_grads
                .iter()
                .map(|g| self.rt.upload_tensor(g))
                .collect::<Result<_>>()?;
            self.last_grads = mean_grads;
            (bufs, loss)
        };

        // Adam apply on device. W_{t−1} comes from the host view, which the
        // sync API pulls fresh on demand.
        if track_delta {
            self.delta.begin_step(&mut self.tr)?;
        }
        let step_buf = self.rt.upload_scalar(self.adam_steps as f32)?;
        let lr = self.cfg.lr;
        if self.lr_buf.as_ref().map(|(v, _)| *v) != Some(lr) {
            self.lr_buf = Some((lr, self.rt.upload_scalar(lr)?));
        }
        // Donated dispatch: trainable/m/v and the mean gradient hand their
        // buffers over; adam_apply's alias map reuses the allocations in
        // place and the outputs are adopted straight back, so one
        // generation of state is live instead of two and nothing is
        // re-uploaded next step.
        let tr_bufs = self.tr.take_device_buffers()?;
        let m_bufs = self.m.take_device_buffers()?;
        let v_bufs = self.v.take_device_buffers()?;
        let mut inputs: Vec<InputBuf> =
            Vec::with_capacity(self.adam_prog.spec.inputs.len());
        inputs.extend(tr_bufs.into_iter().map(InputBuf::Donated));
        inputs.extend(m_bufs.into_iter().map(InputBuf::Donated));
        inputs.extend(v_bufs.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&step_buf));
        inputs.extend(g_bufs.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&self.lr_buf.as_ref().unwrap().1));
        let outs = self.adam_prog.execute_raw_donated(inputs)?;
        let mut outs = outs.into_iter();
        self.tr.adopt_all(&mut outs)?;
        self.m.adopt_all(&mut outs)?;
        self.v.adopt_all(&mut outs)?;
        // Δ_W = W_t − W_{t−1} needs W_t host-side: lazily sync just the
        // trainables (m/v stay device-only for the life of the run). With
        // FF off even the trainables stay device-resident until something
        // (checkpointing, analysis) actually asks for them.
        if track_delta {
            self.delta.end_step(&mut self.tr)?;
        } else {
            // a Δ from before FF shut off must not be served later
            self.delta.clear();
        }
        self.adam_steps += 1;
        self.ffc.on_sgd_step();
        self.flops.sgd_step(&self.fm, global.total_tokens());
        self.log.push(StepRecord {
            step: self.total_steps(),
            kind: StepKind::Sgd,
            loss: mean_loss,
            flops: self.flops.total(),
            seconds: self.timer.elapsed(),
        });
        Ok(mean_loss)
    }

    /// Device path: run `grad_step` in raw mode per micro-batch (only the
    /// loss scalar is downloaded), fold the gradient buffers into a
    /// [`DeviceGradAccumulator`], and return the finalized mean-gradient
    /// buffers ready to donate into `adam_apply`.
    fn accumulate_device(
        &mut self,
        global: &GlobalBatch,
    ) -> Result<(Vec<xla::PjRtBuffer>, f32)> {
        let accum_prog =
            Rc::clone(self.grad_accum_prog.as_ref().expect("checked by sgd_step"));
        let finalize_prog =
            Rc::clone(self.grad_finalize_prog.as_ref().expect("checked by sgd_step"));
        let n = self.tr.len();
        let mut acc = DeviceGradAccumulator::new();
        for micro in &global.micro {
            let (tok, tgt, msk) = self.upload_micro(micro)?;
            let inputs = param_batch_inputs(
                &mut self.tr,
                &mut self.fr,
                self.grad_prog.spec.inputs.len(),
                [&tok, &tgt, &msk],
            )?;
            let outs = self.grad_prog.execute_raw(&inputs)?;
            drop(inputs);
            let mut outs = outs.into_iter();
            let loss_buf = outs.next().expect("grad_step outputs [loss, g..]");
            let loss = self.grad_prog.download_output(&loss_buf, 0)?[0];
            let grads: Vec<xla::PjRtBuffer> = outs.collect();
            debug_assert_eq!(grads.len(), n, "grad_step output arity");
            acc.add_raw(&accum_prog, grads, loss)?;
        }
        let count = acc.count();
        if self.inv_n_buf.as_ref().map(|(c, _)| *c) != Some(count) {
            self.inv_n_buf =
                Some((count, self.rt.upload_scalar(1.0 / count as f32)?));
        }
        acc.finalize(&finalize_prog, &self.inv_n_buf.as_ref().unwrap().1)
    }

    /// Host reference path (`keep_micro_grads`, or artifacts without the
    /// accumulation programs): decode every micro gradient, accumulate in
    /// the host [`GradAccumulator`], and return the mean tensors — which
    /// `sgd_step` then uploads, the O(|trainable|) per-step upload the
    /// device path exists to remove.
    fn accumulate_host(&mut self, global: &GlobalBatch) -> Result<(Vec<Tensor>, f32)> {
        let n = self.tr.len();
        let shapes: Vec<Vec<usize>> =
            (0..n).map(|i| self.tr.shape(i).to_vec()).collect();
        let mut acc = GradAccumulator::new(&shapes);
        if self.keep_micro_grads {
            self.last_micro_grads.clear();
        }
        for micro in &global.micro {
            let (tok, tgt, msk) = self.upload_micro(micro)?;
            let inputs = param_batch_inputs(
                &mut self.tr,
                &mut self.fr,
                self.grad_prog.spec.inputs.len(),
                [&tok, &tgt, &msk],
            )?;
            // Gradients are consumed host-side here, so the decoded path
            // is the right one.
            let out = self.grad_prog.execute_buffers(&inputs)?;
            let loss = out.values[0][0];
            let grads: Vec<&[f32]> =
                (0..n).map(|i| out.values[1 + i].as_slice()).collect();
            acc.add_flat(&grads, loss);
            if self.keep_micro_grads {
                self.last_micro_grads.push(
                    (0..n)
                        .map(|i| {
                            Tensor::from_vec(&shapes[i], out.values[1 + i].clone())
                        })
                        .collect(),
                );
            }
        }
        Ok(acc.take_mean())
    }

    /// Upload one micro-batch's tokens/targets/mask — the only per-step
    /// uploads a steady-state device-accumulation step performs.
    fn upload_micro(
        &self,
        micro: &Batch,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)> {
        Ok((
            self.rt.upload_i32(&micro.tokens, &[micro.b, micro.t])?,
            self.rt.upload_i32(&micro.targets, &[micro.b, micro.t])?,
            self.rt.upload_f32(&micro.mask, &[micro.b, micro.t])?,
        ))
    }

    /// Download mean-gradient buffers into host tensors (analysis
    /// consumers only — the training path never needs this).
    fn download_grads(&self, bufs: &[xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(bufs.len());
        for (i, b) in bufs.iter().enumerate() {
            let v = self.rt.download_f32(b)?;
            out.push(Tensor::from_vec(self.tr.shape(i), v));
        }
        Ok(out)
    }

    /// Evaluate mask-weighted mean loss over a cached batch list
    /// (token-weighted across chunks, matching the in-graph masked mean
    /// exactly). The device buffers for each split upload once, on the
    /// first call, and are reused by every later probe.
    fn eval_batches_loss(
        &mut self,
        which: EvalSet,
        charge_ff: bool,
    ) -> Result<f32> {
        // Detach the cache from `self` so iterating it doesn't pin a borrow
        // across the &mut self program calls; re-attached below.
        let cache = match which {
            EvalSet::Val => self.val_cache.take(),
            EvalSet::Test => self.test_cache.take(),
        };
        let cache = match cache {
            Some(c) => c,
            None => {
                let batches = match which {
                    EvalSet::Val => &self.val_batches,
                    EvalSet::Test => &self.test_batches,
                };
                EvalCache::build(&self.rt, batches)?
            }
        };
        let result = self.eval_cached_loss(&cache, charge_ff);
        match which {
            EvalSet::Val => self.val_cache = Some(cache),
            EvalSet::Test => self.test_cache = Some(cache),
        }
        result
    }

    fn eval_cached_loss(&mut self, cache: &EvalCache, charge_ff: bool) -> Result<f32> {
        let mut total = 0.0f64;
        let mut weight = 0.0f64;
        let mut tokens = 0usize;
        for chunk in cache.chunks() {
            debug_assert!(chunk.mask_sum > 0.0, "EvalCache::build drops zero-mask chunks");
            let inputs = param_batch_inputs(
                &mut self.tr,
                &mut self.fr,
                self.eval_prog.spec.inputs.len(),
                [&chunk.tokens, &chunk.targets, &chunk.mask],
            )?;
            let out = self.eval_prog.execute_buffers(&inputs)?;
            total += out.values[0][0] as f64 * chunk.mask_sum as f64;
            weight += chunk.mask_sum as f64;
            tokens += chunk.total_tokens;
        }
        if charge_ff {
            self.flops.ff_probe(&self.fm, tokens);
        } else {
            self.flops.test_eval(&self.fm, tokens);
        }
        Ok((total / weight.max(1.0)) as f32)
    }

    /// Tiny-validation-set loss (charged as FF inference per the paper).
    pub fn eval_val(&mut self) -> Result<f32> {
        self.eval_batches_loss(EvalSet::Val, true)
    }

    /// Held-out test loss (measurement only: excluded from train time and
    /// chargeable FLOPs).
    pub fn eval_test(&mut self) -> Result<f32> {
        self.timer.pause();
        let loss = self.eval_batches_loss(EvalSet::Test, false);
        self.timer.resume();
        if let Ok(l) = loss {
            let (s, f, t) = (self.total_steps(), self.flops.total(), self.timer.elapsed());
            self.log.test_evals.push((l, s, f, t));
        }
        loss
    }

    /// Run one Fast Forward stage (paper §3): line search along the most
    /// recent Δ_W, stopping when tiny-val loss stops improving.
    pub fn ff_stage(&mut self) -> Result<FfStageStats> {
        let delta = match self.delta.delta() {
            Some(d) => d.to_vec(),
            None if !self.cfg.ff.enabled => bail!(
                "ff_stage on an FF-disabled trainer: Δ_W tracking is gated \
                 on cfg.ff.enabled (baseline steps stay device-resident)"
            ),
            None if self.ffc.is_permanently_off() => bail!(
                "ff_stage after the convergence rule permanently disabled FF"
            ),
            None => bail!("ff_stage before any optimizer step"),
        };
        let grad_norm = list_norm(&self.last_grads);
        let grad_cond = mean_condition_number(&self.last_grads);
        let baseline = self.eval_val()?;

        let max_tau = self.cfg.ff.max_tau;
        let min_rel = self.cfg.ff.min_rel_improvement;
        let result = {
            let mut target = TrainerSearchTarget { trainer: self, delta: &delta };
            line_search_thresholded(&mut target, baseline, max_tau, min_rel)?
        };
        self.record_ff(&result, grad_norm, grad_cond)
    }

    /// Fig 10 probe: run exactly `n_steps` simulated steps with *no* stop
    /// rule, recording val loss at each τ, then restore W_t.
    pub fn ff_probe_fixed(&mut self, n_steps: usize) -> Result<Vec<f32>> {
        let delta = match self.delta.delta() {
            Some(d) => d.to_vec(),
            None if !self.cfg.ff.enabled => bail!(
                "ff_probe on an FF-disabled trainer: Δ_W tracking is gated \
                 on cfg.ff.enabled (baseline steps stay device-resident)"
            ),
            None if self.ffc.is_permanently_off() => bail!(
                "ff_probe after the convergence rule permanently disabled FF"
            ),
            None => bail!("ff_probe before any optimizer step"),
        };
        let snap = self.tr.snapshot();
        let mut losses = Vec::with_capacity(n_steps + 1);
        losses.push(self.eval_val()?);
        for _ in 0..n_steps {
            self.tr.axpy(1.0, &delta);
            losses.push(self.eval_val()?);
        }
        self.tr.restore(&snap);
        Ok(losses)
    }

    fn record_ff(
        &mut self,
        r: &LineSearchResult,
        grad_norm: f64,
        grad_cond: f64,
    ) -> Result<FfStageStats> {
        // Each kept simulated step is a step record (Fig 4 green dots).
        for (i, loss) in r.losses.iter().take(r.tau_star).enumerate() {
            let _ = i;
            self.log.push(StepRecord {
                step: self.total_steps() + 1,
                kind: StepKind::FastForward,
                loss: *loss,
                flops: self.flops.total(),
                seconds: self.timer.elapsed(),
            });
        }
        let stats = FfStageStats {
            stage: self.ffc.n_stages(),
            at_step: self.adam_steps,
            tau_star: r.tau_star,
            probes: r.probes,
            baseline_loss: r.baseline_loss,
            final_loss: r.final_loss,
            grad_norm,
            grad_cond,
        };
        self.ffc.on_ff_stage(stats.clone());
        crate::debug!(
            "FF stage {}: τ*={} probes={} val {:.4}→{:.4}",
            stats.stage,
            stats.tau_star,
            stats.probes,
            stats.baseline_loss,
            stats.final_loss
        );
        Ok(stats)
    }

    // ---------------------------------------------------------------------
    // Run loops
    // ---------------------------------------------------------------------

    /// Drive the controller until the stop rule fires; returns the summary.
    pub fn run(&mut self, stop: &StopRule) -> Result<RunSummary> {
        let mut reached = false;
        loop {
            let max = match stop {
                StopRule::MaxSteps(n) => *n,
                StopRule::TargetLoss { max_steps, .. } => *max_steps,
                StopRule::Convergence { max_steps, .. } => *max_steps,
            };
            if self.adam_steps >= max {
                break;
            }
            let did_ff = match self.ffc.next() {
                FfDecision::Sgd => {
                    self.sgd_step()?;
                    false
                }
                FfDecision::FastForward => {
                    self.ff_stage()?;
                    true
                }
            };
            if let StopRule::TargetLoss { target, eps, eval_every, .. } = stop {
                // Check after every FF stage (a single stage can jump far
                // past the target) and on the SGD cadence otherwise.
                if did_ff || self.adam_steps % eval_every == 0 {
                    let test = self.eval_test()?;
                    if test <= *target + *eps {
                        reached = true;
                        break;
                    }
                }
            }
            if let StopRule::Convergence { tail, .. } = stop {
                if self.ffc.is_permanently_off() {
                    for _ in 0..*tail {
                        self.sgd_step()?;
                    }
                    break;
                }
            }
        }
        let final_test_loss = self.eval_test()?;
        Ok(RunSummary {
            final_test_loss,
            adam_steps: self.adam_steps,
            sim_steps: self.log.n_ff(),
            flops: self.flops,
            train_seconds: self.timer.elapsed(),
            reached_target: reached,
            transfers: self.transfers(),
        })
    }

    // ---------------------------------------------------------------------
    // Analysis hooks
    // ---------------------------------------------------------------------

    /// Evaluate test loss at arbitrary trainable values (Fig 5 plane scan);
    /// restores the current trainables afterwards.
    pub fn eval_test_at(&mut self, trainables: &[Tensor]) -> Result<f32> {
        self.tr.sync_host()?;
        let snap = self.tr.snapshot();
        self.tr.restore(trainables);
        let loss = self.eval_batches_loss(EvalSet::Test, false);
        self.tr.restore(&snap);
        loss
    }

    /// Loss of one example through the eval program (QA scoring). The
    /// example is padded to the eval batch shape with zero-mask rows; the
    /// replicated rows live in a per-trainer scratch that is refilled in
    /// place, so scoring a benchmark allocates nothing per example.
    pub fn eval_example_loss(&mut self, ex: &crate::data::corpus::Example) -> Result<f32> {
        let man = &self.art.manifest;
        let (b, t) = (man.config.model.eval_batch, man.config.model.seq_len);
        anyhow::ensure!(ex.mask.len() == t, "example seq_len {} != model {}", ex.mask.len(), t);
        let scratch = self.qa_scratch.get_or_insert_with(|| ExampleScratch::new(b, t));
        scratch.fill(ex);
        let tok = self.rt.upload_i32(scratch.tokens(), &[b, t])?;
        let tgt = self.rt.upload_i32(scratch.targets(), &[b, t])?;
        let msk = self.rt.upload_f32(scratch.mask(), &[b, t])?;
        let inputs = param_batch_inputs(
            &mut self.tr,
            &mut self.fr,
            self.eval_prog.spec.inputs.len(),
            [&tok, &tgt, &msk],
        )?;
        let out = self.eval_prog.execute_buffers(&inputs)?;
        self.flops.test_eval(&self.fm, b * t);
        Ok(out.values[0][0])
    }

    /// Current trainable snapshot (W_t), syncing any device-ahead state
    /// first — the one download a baseline run ever pays for its params.
    pub fn trainables(&mut self) -> Result<Vec<Tensor>> {
        self.tr.sync_host()?;
        Ok(self.tr.snapshot())
    }

    /// Apply `W += alpha·delta` on the live trainables (bench/probe hook —
    /// the same host axpy a FF simulated step performs).
    pub fn tr_axpy_for_bench(&mut self, delta: &[Tensor], alpha: f32) -> Result<()> {
        self.tr.sync_host()?;
        self.tr.axpy(alpha, delta);
        Ok(())
    }

    /// All current parameters by name (checkpointing). Syncs device-ahead
    /// trainables first; frozen params are never device-written.
    pub fn all_params(&mut self) -> Result<BTreeMap<String, Tensor>> {
        self.tr.sync_host()?;
        let mut out = BTreeMap::new();
        for (name, t) in self.tr.names().iter().zip(self.tr.tensors()) {
            out.insert(name.clone(), t.clone());
        }
        for (name, t) in self.fr.names().iter().zip(self.fr.tensors()) {
            out.insert(name.clone(), t.clone());
        }
        Ok(out)
    }
}

#[derive(Clone, Copy)]
enum EvalSet {
    Val,
    Test,
}

/// Assemble the `[trainables.., frozen.., tokens, targets, mask]` input
/// list shared by every `grad_step`/`eval_loss` dispatch, uploading any
/// stale parameter tensors first. A free function over the two ParamSets
/// (not a `&mut self` method) so the returned borrows stay field-scoped
/// and the caller can still dispatch through the trainer's program
/// handles.
fn param_batch_inputs<'a>(
    tr: &'a mut ParamSet,
    fr: &'a mut ParamSet,
    arity: usize,
    batch: [&'a xla::PjRtBuffer; 3],
) -> Result<Vec<&'a xla::PjRtBuffer>> {
    let mut inputs = Vec::with_capacity(arity);
    inputs.extend(tr.device_buffers()?);
    inputs.extend(fr.device_buffers()?);
    inputs.extend(batch);
    Ok(inputs)
}

/// Line-search target over the live trainer (paper Eq. 2 applied to the
/// real ParamSet, evaluated through the AOT eval program).
struct TrainerSearchTarget<'a> {
    trainer: &'a mut Trainer,
    delta: &'a [Tensor],
}

impl SearchTarget for TrainerSearchTarget<'_> {
    fn apply(&mut self) -> Result<()> {
        self.trainer.tr.axpy(1.0, self.delta);
        Ok(())
    }

    fn revert(&mut self) -> Result<()> {
        self.trainer.tr.axpy(-1.0, self.delta);
        Ok(())
    }

    fn eval(&mut self) -> Result<f32> {
        self.trainer.eval_val()
    }
}
