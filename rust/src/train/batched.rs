//! Batched multi-adapter group stepping: one `*_batched{R}` dispatch
//! drives R independent LoRA runs over a shared frozen base.
//!
//! The AOT layer emits vmapped variants (`grad_step_batched{R}`,
//! `adam_apply_batched{R}`, `eval_loss_batched{R}`) whose leading axis
//! stacks R runs' trainable/optimizer state while the frozen base stays
//! unstacked and shared. XLA compiles the vmapped body to the same
//! per-run arithmetic as the solo programs (pinned bitwise by
//! `python/tests/test_batched.py`), so a packed group promises each
//! member **bit-identical** per-step losses and final test loss versus
//! running solo — while issuing ~R× fewer program dispatches per step.
//!
//! The group steps via the *chained* pair `grad_step_batched{R}` →
//! `adam_apply_batched{R}` (2 dispatches/step), skipping `grad_finalize`
//! entirely: packing requires `global_batch == micro_batch` (one
//! micro-batch per step, no accumulation), and the solo engine's
//! `grad_finalize(×1.0)` over a single micro-batch is a bitwise no-op
//! (proven transitively by the fused-vs-chained python test). Using the
//! fused `train_step_batched{R}` instead would be 1 dispatch/step but is
//! only admissible while fused == chained bitwise — the chained pair
//! matches the solo engine's dispatch sequence by construction.
//!
//! # Per-member transfer attribution
//!
//! The stacked [`ParamSet`]s carry **no** meter: every physical transfer
//! lands on the global [`Runtime::stats`] only, and each member's
//! [`TransferMeter`] is charged its exact slice by hand:
//!
//! * trainable/m/v state: `4·F_t` bytes each (the member's slab of the
//!   stacked upload);
//! * the shared frozen base: `4·F_fr / R` bytes (R ∈ {2, 4} divides the
//!   4-byte word, so the split is exact);
//! * batch tensors, step/lr vectors, loss downloads: the member's own
//!   rows — `4` bytes per member for each `[R]`-shaped scalar vector;
//! * Adam donation: `16·F_t` bytes per step (the member's t/m/v/g slabs
//!   of the donated stacked buffers).
//!
//! Summing member bytes over the group reproduces the global byte delta
//! **exactly** (asserted by `rust/tests/sched_queue.rs` and the
//! `selftest --queue` leg). Member bytes do *not* equal a solo run's
//! bytes — solo uploads the full frozen base and an `inv_n` scalar the
//! batched path never needs — and call *counts* are attributed
//! per-member (one physical call → R member records), so cross-checks
//! compare bytes, never counts. See `docs/transfer-contract.md` §5.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::config::TrainConfig;
use crate::data::batcher::{eval_batches, Batch};
use crate::data::corpus::make_dataset;
use crate::data::pipeline::Pipeline;
use crate::flops::{FlopsCounter, FlopsModel};
use crate::model::init::{init_params, init_with_base};
use crate::model::tensor::Tensor;
use crate::runtime::{Artifact, InputBuf, Manifest, ParamSet, Runtime, TransferMeter};
use crate::train::trainer::{RunSummary, StopRule};

/// One member of a batched group: a label, its training config, and an
/// optional shared base checkpoint (the same `Arc` the solo path hands
/// to [`init_with_base`]).
#[derive(Clone)]
pub struct MemberSpec {
    pub label: String,
    pub cfg: TrainConfig,
    pub base: Option<Arc<BTreeMap<String, Tensor>>>,
}

/// Per-member result of a batched group run. `summary.transfers` is the
/// member's exact byte slice of the group's traffic (see module docs);
/// `dispatches` is the number of program executions the *whole group*
/// issued (shared by every member — the bench divides by R to show the
/// per-run dispatch shrink).
#[derive(Debug, Clone)]
pub struct MemberOutput {
    pub label: String,
    pub summary: RunSummary,
    pub sgd_losses: Vec<f32>,
    pub seconds: f64,
    pub dispatches: usize,
}

/// Whether a run is packable into a batched group for `man`'s artifact:
/// fixed step count (no loss-targeted stopping — members must stay in
/// lock-step), no Fast-Forward stages, exactly one micro-batch per step
/// (the batched chain has no gradient accumulation), and the artifact
/// actually ships batched program variants.
pub fn pack_eligible(man: &Manifest, cfg: &TrainConfig, stop: &StopRule) -> bool {
    matches!(stop, StopRule::MaxSteps(_))
        && !cfg.ff.enabled
        && cfg.global_batch == man.config.model.micro_batch
        && !man.batched_group_sizes().is_empty()
}

fn bitwise_eq(a: &Tensor, b: &Tensor) -> bool {
    a.shape == b.shape
        && a.data.len() == b.data.len()
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Stack each member's tensor for `name` along a new leading run axis.
fn stack_values(
    name: &str,
    shape: &[usize],
    members: &[BTreeMap<String, Tensor>],
) -> Result<Tensor> {
    let mut data = Vec::with_capacity(members.len() * shape.iter().product::<usize>());
    for vals in members {
        let t = vals
            .get(name)
            .ok_or_else(|| anyhow!("missing init value for param '{name}'"))?;
        ensure!(t.shape == shape, "param '{name}': shape {:?} != spec {:?}", t.shape, shape);
        data.extend_from_slice(&t.data);
    }
    let mut stacked = vec![members.len()];
    stacked.extend_from_slice(shape);
    Ok(Tensor::from_vec(&stacked, data))
}

/// Run `steps` Adam steps for `specs.len()` members as one batched
/// group, then evaluate each member's final test loss. Every member's
/// per-step losses and final test loss are bit-identical to running it
/// solo (same seed, same artifact); see the module docs for why.
///
/// The group has no mid-run cancel point: packed members run to
/// completion and join at the batch boundary (`docs/step-pipeline.md`).
pub fn run_batched_group(
    rt: &Arc<Runtime>,
    art: &Arc<Artifact>,
    specs: &[MemberSpec],
    steps: usize,
) -> Result<Vec<MemberOutput>> {
    let man = &art.manifest;
    let ac = &man.config;
    let runs = specs.len();
    ensure!(
        man.batched_group_sizes().contains(&runs),
        "artifact '{}' has no batched programs for R={runs} (available: {:?})",
        man.key,
        man.batched_group_sizes()
    );
    let grad_prog = art.program(&format!("grad_step_batched{runs}"))?;
    let adam_prog = art.program(&format!("adam_apply_batched{runs}"))?;
    let eval_prog = art.program(&format!("eval_loss_batched{runs}"))?;

    let micro = ac.model.micro_batch;
    let seq = ac.model.seq_len;
    let eb = ac.model.eval_batch;
    for s in specs {
        ensure!(s.cfg.artifact == man.key, "member '{}': artifact '{}' != group artifact '{}'",
            s.label, s.cfg.artifact, man.key);
        ensure!(s.cfg.global_batch == micro,
            "member '{}': global_batch {} != micro_batch {} (batched chain has no accumulation)",
            s.label, s.cfg.global_batch, micro);
        ensure!(!s.cfg.ff.enabled, "member '{}': FF runs cannot be packed", s.label);
        ensure!(s.cfg.test_examples == specs[0].cfg.test_examples,
            "member '{}': test_examples {} != {} (eval chunks must align)",
            s.label, s.cfg.test_examples, specs[0].cfg.test_examples);
    }

    // Per-member init over the (required-identical) frozen base. Seeds
    // may differ — they perturb the *adapters* — but the frozen tensors
    // must be bitwise equal across members or the shared unstacked base
    // would silently corrupt every member but one.
    let values: Vec<BTreeMap<String, Tensor>> = specs
        .iter()
        .map(|s| match &s.base {
            Some(b) => init_with_base(ac, s.cfg.seed, b),
            None => init_params(ac, s.cfg.seed),
        })
        .collect();
    for (name, _) in &man.frozen {
        let first = &values[0][name];
        for (i, vals) in values.iter().enumerate().skip(1) {
            ensure!(
                bitwise_eq(first, &vals[name]),
                "member '{}': frozen param '{name}' differs from member '{}' — packed runs \
                 must share a base checkpoint or a seed",
                specs[i].label,
                specs[0].label
            );
        }
    }

    let stacked_spec: Vec<(String, Vec<usize>)> = man
        .trainable
        .iter()
        .map(|(n, s)| {
            let mut shape = vec![runs];
            shape.extend_from_slice(s);
            (n.clone(), shape)
        })
        .collect();
    let mut stacked_vals = BTreeMap::new();
    for (name, shape) in &man.trainable {
        stacked_vals.insert(name.clone(), stack_values(name, shape, &values)?);
    }
    // No meters attached: physical transfers land on the global stats
    // only, and member meters are charged exact slices by hand below.
    let mut tr = ParamSet::from_spec(rt, &stacked_spec, &stacked_vals)?;
    let mut m = ParamSet::zeros_like(rt, &tr);
    let mut v = ParamSet::zeros_like(rt, &tr);
    let mut fr = ParamSet::from_spec(rt, &man.frozen, &values[0])?;
    drop(stacked_vals);
    drop(values);

    let f_t: usize = man.trainable.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    let f_fr: usize = man.frozen.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    ensure!((4 * f_fr) % runs == 0, "frozen bytes {} not divisible by R={runs}", 4 * f_fr);

    let meters: Vec<Arc<TransferMeter>> = (0..runs).map(|_| TransferMeter::new()).collect();

    // Force the initial state upload now so its bytes are attributable,
    // then charge each member its slab of tr/m/v plus 1/R of the base.
    tr.device_buffers()?;
    m.device_buffers()?;
    v.device_buffers()?;
    fr.device_buffers()?;
    for meter in &meters {
        meter.record_upload(4 * f_t); // trainable slab
        meter.record_upload(4 * f_t); // m slab
        meter.record_upload(4 * f_t); // v slab
        meter.record_upload(4 * f_fr / runs); // share of the frozen base
    }

    let mut pipelines = Vec::with_capacity(runs);
    let mut tests = Vec::with_capacity(runs);
    for s in specs {
        let ds = make_dataset(
            &s.cfg.task,
            ac.model.vocab_size,
            seq,
            s.cfg.train_examples,
            s.cfg.test_examples,
            s.cfg.ff.val_examples,
            s.cfg.seed,
        )?;
        pipelines.push(Pipeline::spawn(
            ds.train.clone(),
            micro,
            s.cfg.global_batch,
            s.cfg.seed ^ 0xb47c,
            4,
        ));
        tests.push(eval_batches(&ds.test, eb));
    }
    let chunks = tests[0].len();
    ensure!(
        tests.iter().all(|t| t.len() == chunks),
        "members disagree on eval chunk count — test_examples must match"
    );

    let fm = FlopsModel::for_manifest(man);
    let mut flops = vec![FlopsCounter::default(); runs];
    let mut sgd_losses = vec![Vec::with_capacity(steps); runs];
    let mut dispatches = 0usize;
    let started = Instant::now();

    // One [R]-shaped lr vector for the whole run (member lrs may differ;
    // each member is charged its own 4-byte lane once, like the solo
    // engine's cached lr scalar).
    let lrs: Vec<f32> = specs.iter().map(|s| s.cfg.lr).collect();
    let lr_buf = rt.upload_f32(&lrs, &[runs])?;
    for meter in &meters {
        meter.record_upload(4);
    }

    let bt = micro * seq;
    let mut tok_host = vec![0i32; runs * bt];
    let mut tgt_host = vec![0i32; runs * bt];
    let mut msk_host = vec![0f32; runs * bt];
    for step in 0..steps {
        for (i, pipe) in pipelines.iter_mut().enumerate() {
            let gb = pipe.next();
            ensure!(gb.micro.len() == 1, "packed member got {} micro-batches", gb.micro.len());
            let b: &Batch = &gb.micro[0];
            ensure!(b.b == micro && b.t == seq, "batch shape [{}, {}] != [{micro}, {seq}]", b.b, b.t);
            tok_host[i * bt..(i + 1) * bt].copy_from_slice(&b.tokens);
            tgt_host[i * bt..(i + 1) * bt].copy_from_slice(&b.targets);
            msk_host[i * bt..(i + 1) * bt].copy_from_slice(&b.mask);
        }
        let tok = rt.upload_i32(&tok_host, &[runs, micro, seq])?;
        let tgt = rt.upload_i32(&tgt_host, &[runs, micro, seq])?;
        let msk = rt.upload_f32(&msk_host, &[runs, micro, seq])?;
        for meter in &meters {
            meter.record_upload(4 * bt); // tokens row
            meter.record_upload(4 * bt); // targets row
            meter.record_upload(4 * bt); // mask row
        }

        // grad_step_batched{R}: (t.., fr.., tok, tgt, msk) → (loss[R], g..)
        let mut inputs = tr.device_buffers()?;
        inputs.extend(fr.device_buffers()?);
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        let outs = grad_prog.execute_raw(&inputs)?;
        dispatches += 1;
        let mut outs = outs.into_iter();
        let loss_buf = outs.next().ok_or_else(|| anyhow!("grad_step_batched: no outputs"))?;
        let grads: Vec<xla::PjRtBuffer> = outs.collect();

        let losses = rt.download_f32(&loss_buf)?;
        ensure!(losses.len() == runs, "loss vector has {} lanes != R={runs}", losses.len());
        for (i, meter) in meters.iter().enumerate() {
            meter.record_download(4);
            sgd_losses[i].push(losses[i]);
        }

        // One [R] step vector per step (each member's Adam t may differ
        // in principle, but packed members start together — solo uploads
        // the same 4 bytes per step).
        let step_vec = vec![step as f32; runs];
        let step_buf = rt.upload_f32(&step_vec, &[runs])?;
        for meter in &meters {
            meter.record_upload(4);
        }

        // adam_apply_batched{R}: (t.., m.., v.., step, g.., lr) with
        // t/m/v/g donated — outputs adopt back in the same order.
        let mut inputs: Vec<InputBuf> = Vec::with_capacity(adam_prog.spec.inputs.len());
        inputs.extend(tr.take_device_buffers()?.into_iter().map(InputBuf::Donated));
        inputs.extend(m.take_device_buffers()?.into_iter().map(InputBuf::Donated));
        inputs.extend(v.take_device_buffers()?.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&step_buf));
        inputs.extend(grads.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&lr_buf));
        let outs = adam_prog.execute_raw_donated(inputs)?;
        dispatches += 1;
        let mut outs = outs.into_iter();
        tr.adopt_all(&mut outs)?;
        m.adopt_all(&mut outs)?;
        v.adopt_all(&mut outs)?;
        for (i, meter) in meters.iter().enumerate() {
            meter.record_donation(16 * f_t); // member's t/m/v/g slabs
            flops[i].sgd_step(&fm, bt);
        }
    }

    // Final test eval: chunk j stacks every member's j-th eval batch.
    // A member's mean mirrors LossAccum exactly (f64 mask-weighted);
    // chunks where *every* member is pure padding are skipped like the
    // solo EvalCache skips its zero-mask chunks.
    let ebt = eb * seq;
    let mut totals = vec![0f64; runs];
    let mut weights = vec![0f64; runs];
    let mut eval_tokens = vec![0usize; runs];
    let mut tok_host = vec![0i32; runs * ebt];
    let mut tgt_host = vec![0i32; runs * ebt];
    let mut msk_host = vec![0f32; runs * ebt];
    for j in 0..chunks {
        let mut mask_sums = vec![0f32; runs];
        for i in 0..runs {
            let (b, _) = &tests[i][j];
            ensure!(b.b == eb && b.t == seq, "eval chunk shape [{}, {}] != [{eb}, {seq}]", b.b, b.t);
            tok_host[i * ebt..(i + 1) * ebt].copy_from_slice(&b.tokens);
            tgt_host[i * ebt..(i + 1) * ebt].copy_from_slice(&b.targets);
            msk_host[i * ebt..(i + 1) * ebt].copy_from_slice(&b.mask);
            mask_sums[i] = b.mask.iter().sum();
        }
        if mask_sums.iter().all(|&s| s <= 0.0) {
            continue;
        }
        let tok = rt.upload_i32(&tok_host, &[runs, eb, seq])?;
        let tgt = rt.upload_i32(&tgt_host, &[runs, eb, seq])?;
        let msk = rt.upload_f32(&msk_host, &[runs, eb, seq])?;
        for meter in &meters {
            meter.record_upload(4 * ebt);
            meter.record_upload(4 * ebt);
            meter.record_upload(4 * ebt);
        }
        let mut inputs = tr.device_buffers()?;
        inputs.extend(fr.device_buffers()?);
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        let outs = eval_prog.execute_raw(&inputs)?;
        dispatches += 1;
        let losses = rt.download_f32(&outs[0])?;
        ensure!(losses.len() == runs, "eval loss has {} lanes != R={runs}", losses.len());
        for i in 0..runs {
            meters[i].record_download(4);
            if mask_sums[i] > 0.0 {
                totals[i] += losses[i] as f64 * mask_sums[i] as f64;
                weights[i] += mask_sums[i] as f64;
                eval_tokens[i] += ebt;
            }
        }
    }

    let seconds = started.elapsed().as_secs_f64();
    let mut out = Vec::with_capacity(runs);
    for i in 0..runs {
        flops[i].test_eval(&fm, eval_tokens[i]);
        out.push(MemberOutput {
            label: specs[i].label.clone(),
            summary: RunSummary {
                final_test_loss: (totals[i] / weights[i].max(1.0)) as f32,
                adam_steps: steps,
                sim_steps: 0,
                flops: flops[i],
                train_seconds: seconds,
                reached_target: false,
                cancelled: false,
                // packed groups have no park point: preemption composes
                // with packing at group boundaries only (queue docs)
                parked: false,
                transfers: meters[i].snapshot(),
                store: None,
            },
            sgd_losses: std::mem::take(&mut sgd_losses[i]),
            seconds,
            dispatches,
        });
    }
    Ok(out)
}
