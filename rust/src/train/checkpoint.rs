//! Checkpoint format ("FFCK1"): a JSON header (name/shape table, via the
//! in-repo codec) followed by raw little-endian f32 payloads. Used for the
//! cached pretrained W0 per model size and for trainer save/restore.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 6] = b"FFCK1\n";

pub fn save_params(path: &Path, params: &BTreeMap<String, Tensor>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let entries: Vec<Json> = params
        .iter()
        .map(|(name, t)| {
            Json::obj()
                .set("name", name.as_str())
                .set("shape", t.shape.iter().map(|&d| d as i64).collect::<Vec<i64>>())
        })
        .collect();
    let header = Json::obj().set("params", Json::Arr(entries)).to_string();
    // Write to a temp file and rename into place: a crash mid-write (or a
    // concurrent reader) must never observe a truncated checkpoint.
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for t in params.values() {
            // params is a BTreeMap → iteration order == header order
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("installing {}", path.display()))?;
    Ok(())
}

pub fn load_params(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an FFCK1 checkpoint", path.display());
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    if hlen > 64 << 20 {
        bail!("implausible header length {hlen}");
    }
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;

    let mut out = BTreeMap::new();
    for e in header.get("params").as_arr().unwrap_or(&[]) {
        let name = e.get("name").as_str().unwrap_or_default().to_string();
        let shape: Vec<usize> = e
            .get("shape")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)
            .with_context(|| format!("payload for '{name}'"))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::from_vec(&shape, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_exactly() {
        let mut params = BTreeMap::new();
        params.insert("w".to_string(), Tensor::from_vec(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, f32::MIN_POSITIVE, 1e30]));
        params.insert("b".to_string(), Tensor::from_vec(&[1], vec![-0.125]));
        let dir = std::env::temp_dir().join(format!("ffck-{}", std::process::id()));
        let path = dir.join("test.ffck");
        save_params(&path, &params).unwrap();
        let loaded = load_params(&path).unwrap();
        assert_eq!(params, loaded);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("ffck2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ffck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_params(&path).is_err());
        assert!(load_params(&dir.join("missing.ffck")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
