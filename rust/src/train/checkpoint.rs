//! Checkpoint format ("FFCK1"): a JSON header (name/shape table, via the
//! in-repo codec) followed by raw little-endian f32 payloads. Used for the
//! cached pretrained W0 per model size, for trainer save/restore, and —
//! via [`ParkState`] — for the run queue's preempt/park/resume cycle
//! (docs/queue-serving.md).
//!
//! A park-state checkpoint is an ordinary FFCK1 file whose payload holds
//! the trainables plus both Adam moment sets (`tr/NNNN`, `m/NNNN`,
//! `v/NNNN`) and whose header carries a `park` object with everything a
//! resumed run needs to be bit-identical to an uninterrupted one: Adam
//! step count, FF-controller position, step records, FLOP and transfer
//! totals. Scalars ride in the JSON header: the codec prints f64 (and
//! f32-widened-to-f64) values shortest-round-trip, so floats survive
//! exactly; integer counters are exact up to 2^53, far beyond any real
//! run.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ff::controller::FfStageStats;
use crate::ff::policy::FfPosition;
use crate::flops::FlopsCounter;
use crate::metrics::{StepKind, StepRecord};
use crate::model::tensor::Tensor;
use crate::runtime::TransferSnapshot;
use crate::util::json::Json;

const MAGIC: &[u8; 6] = b"FFCK1\n";

/// Everything a parked run needs to resume bit-identically: optimizer
/// state (trainables + Adam moments, parallel by index), the step/FF
/// position, and the accounting carried across the park (`records`,
/// `flops`, `train_seconds`, `transfers`) so the resumed run's summary
/// reports the *whole* run, not just the tail.
#[derive(Debug, Clone)]
pub struct ParkState {
    pub trainables: Vec<Tensor>,
    /// Adam first moments, same order/shapes as `trainables`.
    pub m: Vec<Tensor>,
    /// Adam second moments, same order/shapes as `trainables`.
    pub v: Vec<Tensor>,
    pub adam_steps: usize,
    pub ff: FfPosition,
    /// Bulk tensor state owned by the FF policy (payload group `fa/`),
    /// e.g. the cosine policy's previous Δ_W. Empty for most policies.
    pub ff_aux: Vec<Tensor>,
    /// `FfConfig::fingerprint()` of the config the snapshot was taken
    /// under. A resume under an edited config fails loudly instead of
    /// silently running with stale scheduling state. Empty = legacy park
    /// file from before the fingerprint existed (check skipped).
    pub ff_fingerprint: String,
    pub stages: Vec<FfStageStats>,
    pub records: Vec<StepRecord>,
    /// `(loss, step, flops, seconds)` rows, as in `RunLog::test_evals`.
    pub test_evals: Vec<(f32, usize, u64, f64)>,
    pub flops: FlopsCounter,
    pub train_seconds: f64,
    /// The run's exact transfer meter at park time — park-sync downloads
    /// included, so billing stays exact across any number of parks.
    pub transfers: TransferSnapshot,
}

/// Write one FFCK1 file: MAGIC, u64-LE header length, JSON header
/// (name/shape table + optional `park` object), then raw LE f32 payloads
/// in `params`' BTreeMap order. Temp-then-rename: a crash mid-write (or a
/// concurrent reader) must never observe a truncated checkpoint.
fn write_ffck<T: std::borrow::Borrow<Tensor>>(
    path: &Path,
    params: &BTreeMap<String, T>,
    park: Option<Json>,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let entries: Vec<Json> = params
        .iter()
        .map(|(name, t)| {
            Json::obj().set("name", name.as_str()).set(
                "shape",
                t.borrow().shape.iter().map(|&d| d as i64).collect::<Vec<i64>>(),
            )
        })
        .collect();
    let mut header = Json::obj().set("params", Json::Arr(entries));
    if let Some(meta) = park {
        header = header.set("park", meta);
    }
    let header = header.to_string();
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for t in params.values() {
            // params is a BTreeMap → iteration order == header order
            let bytes: Vec<u8> =
                t.borrow().data.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("installing {}", path.display()))?;
    Ok(())
}

/// Read one FFCK1 file back: the payload tensors plus the full JSON
/// header (so callers can inspect the optional `park` object). Every
/// malformed case — wrong magic, implausible or truncated header,
/// truncated payload — fails loudly; a leftover `.tmp.<pid>` from a
/// crashed writer is never read (loads go through the installed path
/// only, and the next save overwrites the temp before renaming).
fn read_ffck(path: &Path) -> Result<(BTreeMap<String, Tensor>, Json)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an FFCK1 checkpoint", path.display());
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    if hlen > 64 << 20 {
        bail!("implausible header length {hlen}");
    }
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)
        .with_context(|| format!("reading {hlen}-byte header of {}", path.display()))?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;

    let mut out = BTreeMap::new();
    for e in header.get("params").as_arr().unwrap_or(&[]) {
        let name = e.get("name").as_str().unwrap_or_default().to_string();
        let shape: Vec<usize> = e
            .get("shape")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)
            .with_context(|| format!("payload for '{name}'"))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::from_vec(&shape, data));
    }
    Ok((out, header))
}

pub fn save_params(path: &Path, params: &BTreeMap<String, Tensor>) -> Result<()> {
    write_ffck(path, params, None)
}

pub fn load_params(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    read_ffck(path).map(|(params, _)| params)
}

/// Write a park-state checkpoint. Fails loudly (before touching the
/// filesystem) if the Adam moment sets don't line up with the trainables
/// — an inconsistent park state must never be installed.
pub fn save_park_state(path: &Path, state: &ParkState) -> Result<()> {
    if state.m.len() != state.trainables.len() || state.v.len() != state.trainables.len() {
        bail!(
            "park state is inconsistent: {} trainables but {} Adam m / {} Adam v tensors",
            state.trainables.len(),
            state.m.len(),
            state.v.len()
        );
    }
    let mut params: BTreeMap<String, &Tensor> = BTreeMap::new();
    for (i, t) in state.trainables.iter().enumerate() {
        if state.m[i].shape != t.shape || state.v[i].shape != t.shape {
            bail!(
                "park state is inconsistent: trainable {i} has shape {:?} but Adam m {:?} / v {:?}",
                t.shape,
                state.m[i].shape,
                state.v[i].shape
            );
        }
        params.insert(format!("tr/{i:04}"), t);
        params.insert(format!("m/{i:04}"), &state.m[i]);
        params.insert(format!("v/{i:04}"), &state.v[i]);
    }
    for (i, t) in state.ff_aux.iter().enumerate() {
        params.insert(format!("fa/{i:04}"), t);
    }
    write_ffck(path, &params, Some(park_meta(state)))
}

/// Load a park-state checkpoint. Validates the payload grouping (every
/// entry is `tr/`, `m/` or `v/`, indices dense and in order, shapes
/// consistent) and requires the `park` header object — a plain params
/// checkpoint, a truncated file, or any corrupt header fails here rather
/// than poisoning the resume downstream.
pub fn load_park_state(path: &Path) -> Result<ParkState> {
    let (params, header) = read_ffck(path)?;
    let meta = header.get("park");
    if meta.is_null() {
        bail!("{} has no park metadata — not a park-state checkpoint", path.display());
    }

    let mut trainables: Vec<Tensor> = Vec::new();
    let mut m: Vec<Tensor> = Vec::new();
    let mut v: Vec<Tensor> = Vec::new();
    let mut ff_aux: Vec<Tensor> = Vec::new();
    for (name, t) in params {
        let (group, idx) = name
            .split_once('/')
            .with_context(|| format!("unexpected payload entry '{name}' in park state"))?;
        let slot: usize = idx
            .parse()
            .with_context(|| format!("unexpected payload entry '{name}' in park state"))?;
        let dest = match group {
            "tr" => &mut trainables,
            "m" => &mut m,
            "v" => &mut v,
            "fa" => &mut ff_aux,
            other => bail!("unexpected payload group '{other}' in park state"),
        };
        // BTreeMap order within a group is index order, so each group
        // must arrive dense: a gap means a missing tensor.
        if slot != dest.len() {
            bail!("park state payload has a gap: '{name}' arrived at position {}", dest.len());
        }
        dest.push(t);
    }
    if m.len() != trainables.len() || v.len() != trainables.len() {
        bail!(
            "park state is inconsistent: {} trainables but {} Adam m / {} Adam v tensors",
            trainables.len(),
            m.len(),
            v.len()
        );
    }
    for i in 0..trainables.len() {
        if m[i].shape != trainables[i].shape || v[i].shape != trainables[i].shape {
            bail!(
                "park state is inconsistent: trainable {i} has shape {:?} but Adam m {:?} / v {:?}",
                trainables[i].shape,
                m[i].shape,
                v[i].shape
            );
        }
    }

    let ffj = meta.get("ff");
    // The snapshot is tagged per policy; a pre-PR-10 park file has no
    // "policy" key and is an interval snapshot by construction.
    let ff = match ffj.get("policy").as_str().unwrap_or("interval") {
        "interval" => FfPosition::Interval {
            sgd_since_ff: req_usize(ffj, "sgd_since_ff")?,
            total_sgd: req_usize(ffj, "total_sgd")?,
            interval: req_usize(ffj, "interval")?,
            consecutive_failures: req_usize(ffj, "consecutive_failures")?,
            permanently_off: req_bool(ffj, "permanently_off")?,
        },
        "loss_slope" => FfPosition::LossSlope {
            sgd_since_ff: req_usize(ffj, "sgd_since_ff")?,
            total_sgd: req_usize(ffj, "total_sgd")?,
            consecutive_failures: req_usize(ffj, "consecutive_failures")?,
            permanently_off: req_bool(ffj, "permanently_off")?,
            window: ffj
                .get("window")
                .as_arr()
                .context("park meta: loss-slope 'window' missing")?
                .iter()
                .map(|v| {
                    // widened f32 → f64 on save, so narrowing is exact
                    v.as_f64().map(|x| x as f32).context("park meta: invalid 'window' entry")
                })
                .collect::<Result<Vec<f32>>>()?,
        },
        "cosine" => FfPosition::Cosine {
            sgd_since_ff: req_usize(ffj, "sgd_since_ff")?,
            total_sgd: req_usize(ffj, "total_sgd")?,
            consecutive_failures: req_usize(ffj, "consecutive_failures")?,
            permanently_off: req_bool(ffj, "permanently_off")?,
            last_cosine: req_f64(ffj, "last_cosine")?,
            has_cosine: req_bool(ffj, "has_cosine")?,
        },
        other => bail!("park meta: unknown FF policy tag '{other}'"),
    };
    let ff_fingerprint = meta.get("ff_fingerprint").as_str().unwrap_or("").to_string();
    let flj = meta.get("flops");
    let flops = FlopsCounter {
        train_fwd_bwd: req_u64(flj, "train_fwd_bwd")?,
        adam_updates: req_u64(flj, "adam_updates")?,
        ff_inference: req_u64(flj, "ff_inference")?,
        ff_param_updates: req_u64(flj, "ff_param_updates")?,
        eval_inference: req_u64(flj, "eval_inference")?,
    };
    let txj = meta.get("transfers");
    let transfers = TransferSnapshot {
        uploads: req_u64(txj, "uploads")?,
        uploaded_bytes: req_u64(txj, "uploaded_bytes")?,
        downloads: req_u64(txj, "downloads")?,
        downloaded_bytes: req_u64(txj, "downloaded_bytes")?,
        donations: req_u64(txj, "donations")?,
        donated_bytes: req_u64(txj, "donated_bytes")?,
    };

    let mut records = Vec::new();
    for r in meta.get("records").as_arr().context("park meta: 'records' missing")? {
        let kind = match r.get("kind").as_str().context("park meta: record 'kind' missing")? {
            "sgd" => StepKind::Sgd,
            "ff" => StepKind::FastForward,
            other => bail!("park meta: unknown step kind '{other}'"),
        };
        records.push(StepRecord {
            step: req_usize(r, "step")?,
            kind,
            loss: req_f32(r, "loss")?,
            flops: req_u64(r, "flops")?,
            seconds: req_f64(r, "seconds")?,
        });
    }
    let mut test_evals = Vec::new();
    for e in meta.get("test_evals").as_arr().context("park meta: 'test_evals' missing")? {
        test_evals.push((
            req_f32(e, "loss")?,
            req_usize(e, "step")?,
            req_u64(e, "flops")?,
            req_f64(e, "seconds")?,
        ));
    }
    let mut stages = Vec::new();
    for s in meta.get("stages").as_arr().context("park meta: 'stages' missing")? {
        stages.push(FfStageStats {
            stage: req_usize(s, "stage")?,
            at_step: req_usize(s, "at_step")?,
            tau_star: req_usize(s, "tau_star")?,
            probes: req_usize(s, "probes")?,
            baseline_loss: req_f32(s, "baseline_loss")?,
            final_loss: req_f32(s, "final_loss")?,
            grad_norm: req_f64(s, "grad_norm")?,
            grad_cond: req_f64(s, "grad_cond")?,
        });
    }

    Ok(ParkState {
        trainables,
        m,
        v,
        adam_steps: req_usize(meta, "adam_steps")?,
        ff,
        ff_aux,
        ff_fingerprint,
        stages,
        records,
        test_evals,
        flops,
        train_seconds: req_f64(meta, "train_seconds")?,
        transfers,
    })
}

/// The `park` header object. Counters go out as i64 (exact ≤ 2^53 through
/// the codec's f64), floats as-is: the codec prints shortest-round-trip,
/// so every value read back is bit-identical.
fn park_meta(state: &ParkState) -> Json {
    let ff = match &state.ff {
        FfPosition::Interval {
            sgd_since_ff,
            total_sgd,
            interval,
            consecutive_failures,
            permanently_off,
        } => Json::obj()
            .set("policy", "interval")
            .set("sgd_since_ff", *sgd_since_ff)
            .set("total_sgd", *total_sgd)
            .set("interval", *interval)
            .set("consecutive_failures", *consecutive_failures)
            .set("permanently_off", *permanently_off),
        FfPosition::LossSlope {
            sgd_since_ff,
            total_sgd,
            consecutive_failures,
            permanently_off,
            window,
        } => Json::obj()
            .set("policy", "loss_slope")
            .set("sgd_since_ff", *sgd_since_ff)
            .set("total_sgd", *total_sgd)
            .set("consecutive_failures", *consecutive_failures)
            .set("permanently_off", *permanently_off)
            .set("window", window.iter().map(|&x| x as f64).collect::<Vec<f64>>()),
        FfPosition::Cosine {
            sgd_since_ff,
            total_sgd,
            consecutive_failures,
            permanently_off,
            last_cosine,
            has_cosine,
        } => Json::obj()
            .set("policy", "cosine")
            .set("sgd_since_ff", *sgd_since_ff)
            .set("total_sgd", *total_sgd)
            .set("consecutive_failures", *consecutive_failures)
            .set("permanently_off", *permanently_off)
            .set("last_cosine", *last_cosine)
            .set("has_cosine", *has_cosine),
    };
    let flops = Json::obj()
        .set("train_fwd_bwd", state.flops.train_fwd_bwd as i64)
        .set("adam_updates", state.flops.adam_updates as i64)
        .set("ff_inference", state.flops.ff_inference as i64)
        .set("ff_param_updates", state.flops.ff_param_updates as i64)
        .set("eval_inference", state.flops.eval_inference as i64);
    let transfers = Json::obj()
        .set("uploads", state.transfers.uploads as i64)
        .set("uploaded_bytes", state.transfers.uploaded_bytes as i64)
        .set("downloads", state.transfers.downloads as i64)
        .set("downloaded_bytes", state.transfers.downloaded_bytes as i64)
        .set("donations", state.transfers.donations as i64)
        .set("donated_bytes", state.transfers.donated_bytes as i64);
    let records: Vec<Json> = state
        .records
        .iter()
        .map(|r| {
            Json::obj()
                .set("step", r.step)
                .set("kind", match r.kind {
                    StepKind::Sgd => "sgd",
                    StepKind::FastForward => "ff",
                })
                .set("loss", r.loss as f64)
                .set("flops", r.flops as i64)
                .set("seconds", r.seconds)
        })
        .collect();
    let test_evals: Vec<Json> = state
        .test_evals
        .iter()
        .map(|&(loss, step, flops, seconds)| {
            Json::obj()
                .set("loss", loss as f64)
                .set("step", step)
                .set("flops", flops as i64)
                .set("seconds", seconds)
        })
        .collect();
    let stages: Vec<Json> = state
        .stages
        .iter()
        .map(|s| {
            Json::obj()
                .set("stage", s.stage)
                .set("at_step", s.at_step)
                .set("tau_star", s.tau_star)
                .set("probes", s.probes)
                .set("baseline_loss", s.baseline_loss as f64)
                .set("final_loss", s.final_loss as f64)
                .set("grad_norm", s.grad_norm)
                .set("grad_cond", s.grad_cond)
        })
        .collect();
    Json::obj()
        .set("adam_steps", state.adam_steps)
        .set("train_seconds", state.train_seconds)
        .set("ff", ff)
        .set("ff_fingerprint", state.ff_fingerprint.as_str())
        .set("flops", flops)
        .set("transfers", transfers)
        .set("records", Json::Arr(records))
        .set("test_evals", Json::Arr(test_evals))
        .set("stages", Json::Arr(stages))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .as_usize()
        .with_context(|| format!("park meta: missing or invalid '{key}'"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    let v = j
        .get(key)
        .as_i64()
        .with_context(|| format!("park meta: missing or invalid '{key}'"))?;
    if v < 0 {
        bail!("park meta: '{key}' is negative ({v})");
    }
    Ok(v as u64)
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .as_f64()
        .with_context(|| format!("park meta: missing or invalid '{key}'"))
}

fn req_f32(j: &Json, key: &str) -> Result<f32> {
    // Values were widened f32 → f64 on save, so narrowing is exact.
    Ok(req_f64(j, key)? as f32)
}

fn req_bool(j: &Json, key: &str) -> Result<bool> {
    j.get(key)
        .as_bool()
        .with_context(|| format!("park meta: missing or invalid '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trips_exactly() {
        let mut params = BTreeMap::new();
        params.insert("w".to_string(), Tensor::from_vec(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, f32::MIN_POSITIVE, 1e30]));
        params.insert("b".to_string(), Tensor::from_vec(&[1], vec![-0.125]));
        let dir = std::env::temp_dir().join(format!("ffck-{}", std::process::id()));
        let path = dir.join("test.ffck");
        save_params(&path, &params).unwrap();
        let loaded = load_params(&path).unwrap();
        assert_eq!(params, loaded);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("ffck2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ffck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_params(&path).is_err());
        assert!(load_params(&dir.join("missing.ffck")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn test_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ffck-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A fully-populated park state with pseudo-random tensors plus
    /// hand-picked extreme values in every numeric channel — the
    /// property-style generator behind the round-trip and fault tests.
    fn park_fixture(seed: u64) -> ParkState {
        let mut rng = Rng::new(seed);
        let n_t = 1 + rng.below(3);
        let mut trainables = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for _ in 0..n_t {
            let rows = 2 + rng.below(3);
            let cols = 2 + rng.below(4);
            let mut mk = |rng: &mut Rng| {
                let data: Vec<f32> =
                    (0..rows * cols).map(|_| (rng.next_f32() - 0.5) * 1e6).collect();
                Tensor::from_vec(&[rows, cols], data)
            };
            trainables.push(mk(&mut rng));
            m.push(mk(&mut rng));
            v.push(mk(&mut rng));
        }
        // extremes: subnormal-boundary, huge, negative zero, max finite
        trainables[0].data[0] = f32::MIN_POSITIVE;
        trainables[0].data[1] = 1e30;
        m[0].data[0] = -0.0;
        v[0].data[0] = f32::MAX;
        ParkState {
            trainables,
            m,
            v,
            adam_steps: rng.below(10_000),
            ff: FfPosition::Interval {
                sgd_since_ff: rng.below(50),
                total_sgd: rng.below(10_000),
                interval: 1 + rng.below(24),
                consecutive_failures: rng.below(4),
                permanently_off: seed % 2 == 0,
            },
            ff_aux: Vec::new(),
            ff_fingerprint: format!("v1|fixture|{seed}"),
            stages: vec![FfStageStats {
                stage: 0,
                at_step: 7,
                tau_star: 5,
                probes: 6,
                baseline_loss: 1.25e-7,
                final_loss: f32::MAX,
                grad_norm: 1.0 / 3.0,
                grad_cond: 7e300,
            }],
            records: vec![
                StepRecord {
                    step: 0,
                    kind: StepKind::Sgd,
                    loss: 0.1 + rng.next_f32(),
                    flops: (1u64 << 52) + 12_345, // near the 2^53 exactness bound
                    seconds: 1.0 / 3.0,
                },
                StepRecord {
                    step: 1,
                    kind: StepKind::FastForward,
                    loss: f32::MIN_POSITIVE,
                    flops: 0,
                    seconds: 0.0,
                },
            ],
            test_evals: vec![(0.5 + rng.next_f32(), 10, 1u64 << 40, 2.0 / 7.0)],
            flops: FlopsCounter {
                train_fwd_bwd: (1u64 << 52) + 1,
                adam_updates: 123_456_789_012_345,
                ff_inference: rng.next_u64() >> 12, // keep < 2^53
                ff_param_updates: 7,
                eval_inference: 0,
            },
            train_seconds: 12.625 + rng.next_f64(),
            transfers: TransferSnapshot {
                uploads: 3,
                uploaded_bytes: (1u64 << 33) + 17,
                downloads: rng.below(1 << 20) as u64,
                downloaded_bytes: 0,
                donations: 1,
                donated_bytes: (1u64 << 52) + 99,
            },
        }
    }

    fn assert_park_eq(a: &ParkState, b: &ParkState) {
        assert_eq!(a.trainables, b.trainables);
        assert_eq!(a.m, b.m);
        assert_eq!(a.v, b.v);
        assert_eq!(a.adam_steps, b.adam_steps);
        assert_eq!(a.ff, b.ff);
        assert_eq!(a.ff_aux, b.ff_aux);
        assert_eq!(a.ff_fingerprint, b.ff_fingerprint);
        assert_eq!(a.train_seconds.to_bits(), b.train_seconds.to_bits());
        assert_eq!(a.transfers, b.transfers);
        // FlopsCounter has no PartialEq: compare field by field
        assert_eq!(a.flops.train_fwd_bwd, b.flops.train_fwd_bwd);
        assert_eq!(a.flops.adam_updates, b.flops.adam_updates);
        assert_eq!(a.flops.ff_inference, b.flops.ff_inference);
        assert_eq!(a.flops.ff_param_updates, b.flops.ff_param_updates);
        assert_eq!(a.flops.eval_inference, b.flops.eval_inference);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.step, y.step);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.flops, y.flops);
            assert_eq!(x.seconds.to_bits(), y.seconds.to_bits());
        }
        assert_eq!(a.test_evals.len(), b.test_evals.len());
        for (x, y) in a.test_evals.iter().zip(&b.test_evals) {
            assert_eq!(x.0.to_bits(), y.0.to_bits());
            assert_eq!((x.1, x.2), (y.1, y.2));
            assert_eq!(x.3.to_bits(), y.3.to_bits());
        }
        assert_eq!(a.stages.len(), b.stages.len());
        for (x, y) in a.stages.iter().zip(&b.stages) {
            assert_eq!((x.stage, x.at_step, x.tau_star, x.probes), (y.stage, y.at_step, y.tau_star, y.probes));
            assert_eq!(x.baseline_loss.to_bits(), y.baseline_loss.to_bits());
            assert_eq!(x.final_loss.to_bits(), y.final_loss.to_bits());
            assert_eq!(x.grad_norm.to_bits(), y.grad_norm.to_bits());
            assert_eq!(x.grad_cond.to_bits(), y.grad_cond.to_bits());
        }
    }

    #[test]
    fn park_state_round_trips_bit_exactly_over_random_payloads() {
        let dir = test_dir("park-rt");
        for seed in [1u64, 7, 42, 0xffcc, 0xdead_beef] {
            let state = park_fixture(seed);
            let path = dir.join(format!("park-{seed}.ffpk"));
            save_park_state(&path, &state).unwrap();
            let loaded = load_park_state(&path).unwrap();
            assert_park_eq(&state, &loaded);
            // a park-state file is still a valid FFCK1 params file
            let raw = load_params(&path).unwrap();
            assert_eq!(raw.len(), 3 * state.trainables.len());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn per_policy_positions_and_aux_round_trip_bit_exactly() {
        let dir = test_dir("park-policy");
        // loss-slope: window floats must survive exactly, extremes included
        let mut slope = park_fixture(11);
        slope.ff = FfPosition::LossSlope {
            sgd_since_ff: 3,
            total_sgd: 17,
            consecutive_failures: 1,
            permanently_off: false,
            window: vec![1.25, f32::MIN_POSITIVE, 0.333_333_34, -0.0, 1e30],
        };
        let path = dir.join("slope.ffpk");
        save_park_state(&path, &slope).unwrap();
        assert_park_eq(&slope, &load_park_state(&path).unwrap());

        // cosine: scalar position plus the previous Δ_W through `fa/`
        let mut cos = park_fixture(12);
        cos.ff = FfPosition::Cosine {
            sgd_since_ff: 2,
            total_sgd: 9,
            consecutive_failures: 0,
            permanently_off: false,
            last_cosine: 0.912_345_678_901_234_5,
            has_cosine: true,
        };
        cos.ff_aux = vec![
            Tensor::from_vec(&[2, 2], vec![0.5, -1.5, f32::MIN_POSITIVE, 3.0]),
            Tensor::from_vec(&[3], vec![1.0, 2.0, -0.0]),
        ];
        let path = dir.join("cosine.ffpk");
        save_park_state(&path, &cos).unwrap();
        let loaded = load_park_state(&path).unwrap();
        assert_park_eq(&cos, &loaded);
        // the aux tensors are ordinary payload entries alongside tr/m/v
        let raw = load_params(&path).unwrap();
        assert_eq!(raw.len(), 3 * cos.trainables.len() + 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_park_header_parses_as_interval_with_no_fingerprint() {
        // A pre-PR-10 park file has a flat untagged `ff` object and no
        // `ff_fingerprint`; it must load as an Interval snapshot with an
        // empty fingerprint (resume-time config check skipped).
        let dir = test_dir("park-legacy");
        let state = park_fixture(13);
        let mut params: BTreeMap<String, &Tensor> = BTreeMap::new();
        for (i, t) in state.trainables.iter().enumerate() {
            params.insert(format!("tr/{i:04}"), t);
            params.insert(format!("m/{i:04}"), &state.m[i]);
            params.insert(format!("v/{i:04}"), &state.v[i]);
        }
        let mut meta = park_meta(&state);
        if let Json::Obj(map) = &mut meta {
            map.remove("ff_fingerprint");
            if let Some(Json::Obj(ff)) = map.get_mut("ff") {
                ff.remove("policy");
            }
        }
        let path = dir.join("legacy.ffpk");
        write_ffck(&path, &params, Some(meta)).unwrap();
        let loaded = load_park_state(&path).unwrap();
        assert_eq!(loaded.ff, state.ff);
        assert!(loaded.ff_fingerprint.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_policy_tag_fails_loudly() {
        let dir = test_dir("park-badtag");
        let state = park_fixture(14);
        let mut params: BTreeMap<String, &Tensor> = BTreeMap::new();
        for (i, t) in state.trainables.iter().enumerate() {
            params.insert(format!("tr/{i:04}"), t);
            params.insert(format!("m/{i:04}"), &state.m[i]);
            params.insert(format!("v/{i:04}"), &state.v[i]);
        }
        let mut meta = park_meta(&state);
        if let Json::Obj(map) = &mut meta {
            if let Some(Json::Obj(ff)) = map.get_mut("ff") {
                ff.insert("policy".into(), Json::Str("bogus".into()));
            }
        }
        let path = dir.join("badtag.ffpk");
        write_ffck(&path, &params, Some(meta)).unwrap();
        let err = load_park_state(&path).unwrap_err();
        assert!(err.to_string().contains("unknown FF policy tag"), "got: {err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_park_files_fail_loudly() {
        let dir = test_dir("park-trunc");
        let path = dir.join("park.ffpk");
        save_park_state(&path, &park_fixture(3)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // payload cut short: the last tensor's read_exact must fail
        let cut = dir.join("cut-payload.ffpk");
        std::fs::write(&cut, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load_park_state(&cut).is_err());
        // header cut short: file ends inside the JSON header
        let cut_h = dir.join("cut-header.ffpk");
        std::fs::write(&cut_h, &bytes[..20]).unwrap();
        assert!(load_park_state(&cut_h).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_magic_header_or_length_fails_loudly() {
        let dir = test_dir("park-corrupt");
        let path = dir.join("park.ffpk");
        save_park_state(&path, &park_fixture(4)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // corrupt magic
        let mut b = bytes.clone();
        b[0] = b'X';
        let p = dir.join("bad-magic.ffpk");
        std::fs::write(&p, &b).unwrap();
        assert!(load_park_state(&p).is_err());
        // corrupt first header byte (offset 14 = 6 magic + 8 length):
        // '{' becomes 'X', guaranteeing a JSON parse error
        let mut b = bytes.clone();
        b[14] = b'X';
        let p = dir.join("bad-header.ffpk");
        std::fs::write(&p, &b).unwrap();
        assert!(load_park_state(&p).is_err());
        // implausible header length
        let mut b = Vec::from(*MAGIC);
        b.extend_from_slice(&u64::MAX.to_le_bytes());
        b.extend_from_slice(b"junk");
        let p = dir.join("bad-length.ffpk");
        std::fs::write(&p, &b).unwrap();
        assert!(load_park_state(&p).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn leftover_tmp_from_crashed_writer_never_poisons_a_resume() {
        let dir = test_dir("park-tmp");
        let path = dir.join("park.ffpk");
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        // simulate a crash mid-write: a garbage temp file, no installed file
        std::fs::write(&tmp, b"half-written garbage").unwrap();
        // the loader only ever reads the installed path — missing → error
        assert!(load_park_state(&path).is_err());
        // the next save overwrites the temp and installs atomically
        let state = park_fixture(5);
        save_park_state(&path, &state).unwrap();
        assert!(!tmp.exists(), "temp file must be renamed away, not left behind");
        assert_park_eq(&state, &load_park_state(&path).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plain_params_checkpoint_is_rejected_as_park_state() {
        let dir = test_dir("park-plain");
        let path = dir.join("w0.ffck");
        let mut params = BTreeMap::new();
        params.insert("w".to_string(), Tensor::from_vec(&[2], vec![1.0, 2.0]));
        save_params(&path, &params).unwrap();
        let err = load_park_state(&path).unwrap_err();
        assert!(err.to_string().contains("no park metadata"), "got: {err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn inconsistent_moment_sets_are_rejected_at_save_time() {
        let dir = test_dir("park-shape");
        let mut state = park_fixture(6);
        state.m.pop();
        assert!(save_park_state(&dir.join("a.ffpk"), &state).is_err());
        let mut state = park_fixture(6);
        state.v[0] = Tensor::from_vec(&[1], vec![0.0]);
        assert!(save_park_state(&dir.join("b.ffpk"), &state).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
