//! Synthetic corpus generators (the dataset substitutes — DESIGN.md
//! §Substitutions).
//!
//! Every example is a `seq_len + 1` token sequence plus a per-target loss
//! mask; `tokens = seq[..T]`, `targets = seq[1..]`. Generators are seeded
//! and deterministic: the baseline and FF runs of an experiment must see
//! byte-identical data order, as in the paper's protocol.
//!
//! * `medical`  — narrow-domain first-order Markov chain (sparse learned
//!   transitions over ¼ of the content vocab) ↔ Clinical Guidelines.
//! * `instruct` — prompt → response with the response a *deterministic
//!   per-token function* of the prompt (so it is learnable) and loss only
//!   on response positions ↔ decontaminated Evol.
//! * `chat`     — multi-turn dialogues with a per-dialogue topic region and
//!   USR/ASST speaker tags ↔ filtered ultrachat.
//! * `pile`     — wide-vocab Markov mix, the pretraining substrate that
//!   manufactures W0 before finetuning experiments.

use crate::data::vocab::{self, Vocab};
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    /// seq_len + 1 token ids.
    pub seq: Vec<i32>,
    /// seq_len loss-mask entries aligned with targets = seq[1..].
    pub mask: Vec<f32>,
}

impl Example {
    pub fn tokens(&self) -> &[i32] {
        &self.seq[..self.seq.len() - 1]
    }

    pub fn targets(&self) -> &[i32] {
        &self.seq[1..]
    }
}

/// A generated split set: train / test (1K, paper §4) / tiny val (32).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub task: String,
    pub train: Vec<Example>,
    pub test: Vec<Example>,
    pub val: Vec<Example>,
}

/// Sparse first-order Markov chain over a content-id range: each state has
/// `branch` successors with random weights — low-entropy enough that a tiny
/// LM learns it, high-entropy enough that loss stays non-trivial.
struct Markov {
    range: std::ops::Range<usize>,
    succ: Vec<Vec<(usize, f64)>>,
}

impl Markov {
    fn new(range: std::ops::Range<usize>, branch: usize, rng: &mut Rng) -> Markov {
        let n = range.len();
        let succ = (0..n)
            .map(|_| {
                (0..branch)
                    .map(|_| (rng.below(n), 0.25 + rng.next_f64()))
                    .collect()
            })
            .collect();
        Markov { range, succ }
    }

    fn start(&self, rng: &mut Rng) -> usize {
        self.range.start + rng.below(self.range.len())
    }

    fn next(&self, state: usize, rng: &mut Rng) -> usize {
        let local = state - self.range.start;
        let choices = &self.succ[local];
        let weights: Vec<f64> = choices.iter().map(|(_, w)| *w).collect();
        self.range.start + choices[rng.categorical(&weights)].0
    }

    fn walk(&self, len: usize, rng: &mut Rng, v: &Vocab, out: &mut Vec<i32>) {
        let mut s = self.start(rng);
        for _ in 0..len {
            out.push(v.content(s));
            s = self.next(s, rng);
        }
    }
}

fn pad_to(seq: &mut Vec<i32>, mask: &mut Vec<f32>, seq_len: usize) {
    seq.truncate(seq_len + 1);
    mask.truncate(seq_len);
    while seq.len() < seq_len + 1 {
        seq.push(vocab::PAD);
    }
    while mask.len() < seq_len {
        mask.push(0.0);
    }
    // positions predicting PAD carry no loss
    for i in 0..seq_len {
        if seq[i + 1] == vocab::PAD {
            mask[i] = 0.0;
        }
    }
}

/// Medical: BOS + one long narrow-domain Markov walk.
fn gen_medical(v: &Vocab, seq_len: usize, chain: &Markov, rng: &mut Rng) -> Example {
    let mut seq = vec![vocab::BOS];
    chain.walk(seq_len, rng, v, &mut seq);
    let mut mask = vec![1.0; seq_len];
    pad_to(&mut seq, &mut mask, seq_len);
    Example { seq, mask }
}

/// Instruct: BOS prompt SEP response EOS; response token i is a fixed
/// per-position permutation of prompt token i (learnable mapping); loss
/// only on response+EOS positions — exercising the same loss-mask path the
/// paper uses ("loss is only based on response completion").
fn gen_instruct(v: &Vocab, seq_len: usize, perm: &[usize], rng: &mut Rng) -> Example {
    let pd = v.instruct_prompt_domain();
    let rd = v.instruct_response_domain();
    let max_prompt = (seq_len - 2) / 2;
    let plen = 3 + rng.below(max_prompt.saturating_sub(3).max(1));
    let prompt: Vec<usize> = (0..plen).map(|_| pd.start + rng.below(pd.len())).collect();

    let mut seq = vec![vocab::BOS];
    let mut mask = vec![0.0]; // target of BOS is first prompt token: no loss
    for &p in &prompt {
        seq.push(v.content(p));
        mask.push(0.0);
    }
    seq.push(vocab::SEP);
    mask.pop(); // mask aligns with targets; rebuild below instead
    // Rebuild mask precisely: mask[i] governs target seq[i+1].
    let mut mask = vec![0.0; seq.len() - 1]; // predicting prompt+SEP: no loss
    for &p in &prompt {
        let local = p - pd.start;
        let resp = rd.start + perm[local % perm.len()] % rd.len();
        seq.push(v.content(resp));
        mask.push(1.0); // predicting this response token: loss
    }
    seq.push(vocab::EOS);
    mask.push(1.0);
    pad_to(&mut seq, &mut mask, seq_len);
    Example { seq, mask }
}

/// Chat: alternating USR/ASST utterances, all drawn from one per-dialogue
/// topic chain; loss on every non-pad position (as in ultrachat tuning).
fn gen_chat(
    v: &Vocab,
    seq_len: usize,
    topics: &[Markov],
    rng: &mut Rng,
) -> Example {
    let topic = rng.below(topics.len());
    let chain = &topics[topic];
    let mut seq = vec![vocab::BOS];
    let mut who = 0;
    while seq.len() < seq_len + 1 {
        seq.push(if who == 0 { vocab::USR } else { vocab::ASST });
        let ulen = 4 + rng.below(12);
        chain.walk(ulen, rng, v, &mut seq);
        who ^= 1;
    }
    let mut mask = vec![1.0; seq_len];
    pad_to(&mut seq, &mut mask, seq_len);
    Example { seq, mask }
}

/// Pile mix: wide Markov chain across the whole content vocab.
fn gen_pile(v: &Vocab, seq_len: usize, chain: &Markov, rng: &mut Rng) -> Example {
    let mut seq = vec![vocab::BOS];
    chain.walk(seq_len, rng, v, &mut seq);
    let mut mask = vec![1.0; seq_len];
    pad_to(&mut seq, &mut mask, seq_len);
    Example { seq, mask }
}

/// Generate a full dataset for (task, vocab, seq_len). Streams are split
/// per purpose so e.g. growing the train set never changes test examples.
pub fn make_dataset(
    task: &str,
    vocab_size: usize,
    seq_len: usize,
    n_train: usize,
    n_test: usize,
    n_val: usize,
    seed: u64,
) -> anyhow::Result<Dataset> {
    let v = Vocab::new(vocab_size);
    let root = Rng::new(seed ^ 0xda7a);
    let mut structure_rng = root.fork(&format!("{task}-structure"));

    // Task structure (transition tables, permutation) is fixed per task+seed.
    let medical_chain = Markov::new(v.medical_domain(), 6, &mut structure_rng);
    let pile_chain = Markov::new(0..v.n_content(), 12, &mut structure_rng);
    let n_topics = 4;
    let topics: Vec<Markov> = (0..n_topics)
        .map(|t| Markov::new(v.chat_topic_domain(t, n_topics), 6, &mut structure_rng))
        .collect();
    let perm: Vec<usize> = {
        let mut p: Vec<usize> = (0..v.instruct_prompt_domain().len()).collect();
        structure_rng.shuffle(&mut p);
        p
    };

    let gen_split = |name: &str, n: usize| -> anyhow::Result<Vec<Example>> {
        let mut rng = root.fork(&format!("{task}-{name}"));
        (0..n)
            .map(|_| {
                Ok(match task {
                    "medical" => gen_medical(&v, seq_len, &medical_chain, &mut rng),
                    "instruct" => gen_instruct(&v, seq_len, &perm, &mut rng),
                    "chat" => gen_chat(&v, seq_len, &topics, &mut rng),
                    "pile" => gen_pile(&v, seq_len, &pile_chain, &mut rng),
                    other => anyhow::bail!("unknown task '{other}'"),
                })
            })
            .collect()
    };

    Ok(Dataset {
        task: task.to_string(),
        train: gen_split("train", n_train)?,
        test: gen_split("test", n_test)?,
        val: gen_split("val", n_val)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(task: &str) -> Dataset {
        make_dataset(task, 512, 64, 32, 16, 8, 7).unwrap()
    }

    #[test]
    fn shapes_and_determinism() {
        for task in ["medical", "instruct", "chat", "pile"] {
            let a = ds(task);
            let b = ds(task);
            assert_eq!(a.train, b.train, "{task}");
            assert_eq!(a.train.len(), 32);
            assert_eq!(a.test.len(), 16);
            assert_eq!(a.val.len(), 8);
            for ex in a.train.iter().chain(&a.test).chain(&a.val) {
                assert_eq!(ex.seq.len(), 65);
                assert_eq!(ex.mask.len(), 64);
                assert!(ex.seq.iter().all(|t| (0..512).contains(t)));
            }
        }
        assert!(make_dataset("nope", 512, 64, 1, 1, 1, 0).is_err());
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let a = ds("medical");
        assert_ne!(a.train[0], a.test[0]);
        // growing train must not perturb test
        let bigger = make_dataset("medical", 512, 64, 64, 16, 8, 7).unwrap();
        assert_eq!(a.test, bigger.test);
        assert_eq!(a.train[..32], bigger.train[..32]);
    }

    #[test]
    fn medical_is_narrow_domain() {
        let v = Vocab::new(512);
        let a = ds("medical");
        let dom = v.medical_domain();
        for ex in &a.train {
            for &t in ex.seq.iter().filter(|&&t| t >= vocab::N_RESERVED as i32) {
                let idx = t as usize - vocab::N_RESERVED;
                assert!(dom.contains(&idx), "token {t} outside medical domain");
            }
        }
    }

    #[test]
    fn instruct_masks_prompt_only() {
        let a = ds("instruct");
        for ex in &a.train {
            let sep = ex.seq.iter().position(|&t| t == vocab::SEP).unwrap();
            // loss starts only after SEP (mask[i] governs target seq[i+1])
            for i in 0..sep {
                assert_eq!(ex.mask[i], 0.0, "loss on prompt at {i}");
            }
            assert!(ex.mask.iter().sum::<f32>() > 0.0, "no loss at all");
            // the masked-in positions predict response-domain or EOS tokens
            let v = Vocab::new(512);
            let rd = v.instruct_response_domain();
            for i in 0..ex.mask.len() {
                if ex.mask[i] == 1.0 {
                    let t = ex.seq[i + 1];
                    let ok = t == vocab::EOS
                        || rd.contains(&((t as usize).saturating_sub(vocab::N_RESERVED)));
                    assert!(ok, "masked-in target {t} not response/EOS");
                }
            }
        }
    }

    #[test]
    fn instruct_response_is_function_of_prompt() {
        // identical prompts ⇒ identical responses (learnability guarantee)
        let a = make_dataset("instruct", 512, 64, 256, 1, 1, 3).unwrap();
        use std::collections::HashMap;
        let mut seen: HashMap<Vec<i32>, Vec<i32>> = HashMap::new();
        for ex in &a.train {
            let sep = ex.seq.iter().position(|&t| t == vocab::SEP).unwrap();
            let prompt = ex.seq[1..sep].to_vec();
            let resp: Vec<i32> = ex.seq[sep + 1..].iter().copied()
                .take_while(|&t| t != vocab::EOS && t != vocab::PAD)
                .collect();
            if let Some(prev) = seen.get(&prompt) {
                assert_eq!(prev, &resp);
            } else {
                seen.insert(prompt, resp);
            }
        }
    }

    #[test]
    fn chat_has_speaker_structure_and_topics() {
        let a = ds("chat");
        let mut any_usr = false;
        for ex in &a.train {
            any_usr |= ex.seq.contains(&vocab::USR);
            assert!(ex.seq.contains(&vocab::ASST) || ex.seq.contains(&vocab::USR));
        }
        assert!(any_usr);
    }

    #[test]
    fn pad_positions_carry_no_loss() {
        let a = ds("instruct");
        for ex in &a.train {
            for i in 0..ex.mask.len() {
                if ex.seq[i + 1] == vocab::PAD {
                    assert_eq!(ex.mask[i], 0.0);
                }
            }
        }
    }
}
