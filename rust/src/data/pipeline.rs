//! Streaming prefetch pipeline: a producer thread assembles global batches
//! ahead of the trainer, through a bounded channel that provides
//! backpressure (tokio replacement — std threads + sync_channel).
//!
//! Batch assembly is cheap for synthetic corpora, but the pipeline keeps
//! data preparation fully off the hot loop and is the module a real
//! deployment would extend with tokenization / disk I/O workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::data::batcher::{Batcher, GlobalBatch};
use crate::data::corpus::Example;

pub struct Pipeline {
    rx: Receiver<GlobalBatch>,
    handle: Option<JoinHandle<()>>,
    produced: usize,
    producer_count: Arc<AtomicUsize>,
}

impl Pipeline {
    /// Spawn a producer streaming shuffled global batches forever (the
    /// trainer decides when to stop by dropping the pipeline).
    pub fn spawn(
        examples: Vec<Example>,
        micro_batch: usize,
        global_batch: usize,
        seed: u64,
        depth: usize,
    ) -> Pipeline {
        let (tx, rx) = sync_channel(depth.max(1));
        let producer_count = Arc::new(AtomicUsize::new(0));
        let pc = Arc::clone(&producer_count);
        let handle = std::thread::Builder::new()
            .name("ff-data".into())
            .spawn(move || {
                let mut batcher = Batcher::new(&examples, micro_batch, global_batch, seed);
                loop {
                    let g = batcher.next_global();
                    pc.fetch_add(1, Ordering::Relaxed);
                    if tx.send(g).is_err() {
                        return; // consumer dropped
                    }
                }
            })
            .expect("spawn data thread");
        Pipeline { rx, handle: Some(handle), produced: 0, producer_count }
    }

    /// Blocking fetch of the next global batch.
    pub fn next(&mut self) -> GlobalBatch {
        let g = self.rx.recv().expect("data thread died");
        self.produced += 1;
        g
    }

    /// Non-blocking fetch (used by tests and the backpressure probe).
    pub fn try_next(&mut self) -> Option<GlobalBatch> {
        match self.rx.try_recv() {
            Ok(g) => {
                self.produced += 1;
                Some(g)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => panic!("data thread died"),
        }
    }

    pub fn produced(&self) -> usize {
        self.produced
    }

    /// Batches the producer thread has generated so far (backpressure probe).
    pub fn producer_generated(&self) -> usize {
        self.producer_count.load(Ordering::Relaxed)
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // The producer may be blocked mid-`send` on a full channel; drain
        // whatever is buffered so it can complete that send, then detach
        // (drop the JoinHandle without joining). Joining here could
        // deadlock — the receiver is a field of `self` and only disconnects
        // *after* this Drop returns, and the producer runs until a send
        // fails. Once `self.rx` drops with the rest of the struct, the
        // producer's next send errors and the detached thread exits.
        if let Some(handle) = self.handle.take() {
            while self.rx.try_recv().is_ok() {}
            drop(handle);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::make_dataset;

    fn examples() -> Vec<Example> {
        make_dataset("chat", 512, 64, 64, 4, 4, 9).unwrap().train
    }

    #[test]
    fn streams_same_batches_as_direct_batcher() {
        let exs = examples();
        let mut direct = Batcher::new(&exs, 8, 16, 3);
        let mut pipe = Pipeline::spawn(exs.clone(), 8, 16, 3, 2);
        for _ in 0..10 {
            let a = direct.next_global();
            let b = pipe.next();
            assert_eq!(a.micro.len(), b.micro.len());
            for (x, y) in a.micro.iter().zip(b.micro.iter()) {
                assert_eq!(x.tokens, y.tokens);
                assert_eq!(x.mask, y.mask);
            }
        }
        assert_eq!(pipe.produced(), 10);
    }

    #[test]
    fn bounded_depth_applies_backpressure() {
        let exs = examples();
        let pipe = Pipeline::spawn(exs, 8, 16, 0, 2);
        // Give the producer time to run ahead, then confirm it stopped at
        // the bound: depth (2) + at most 1 blocked in-flight send.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let generated = pipe.producer_generated();
        assert!((1..=3).contains(&generated), "generated {generated}");
    }

    #[test]
    fn drop_does_not_hang() {
        let exs = examples();
        let pipe = Pipeline::spawn(exs, 8, 16, 0, 1);
        drop(pipe); // must return promptly
    }
}
