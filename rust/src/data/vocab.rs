//! Vocabulary layout shared by all synthetic corpora.
//!
//! Ids 0..8 are reserved control tokens; content ids partition into a
//! general region plus per-task "domain" regions so the three finetuning
//! tasks have genuinely different token distributions (the medical corpus
//! is narrow-domain, chat dialogues are topic-clustered, etc.).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Vocab {
    pub size: usize,
}

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// Instruction/response boundary.
pub const SEP: i32 = 3;
/// Chat speaker tags.
pub const USR: i32 = 4;
pub const ASST: i32 = 5;
/// QA answer markers (yes/no/maybe candidates for the §5.2 benchmark).
pub const ANS_YES: i32 = 6;
pub const ANS_NO: i32 = 7;
pub const ANS_MAYBE: i32 = 8;

pub const N_RESERVED: usize = 9;

impl Vocab {
    pub fn new(size: usize) -> Vocab {
        assert!(size > 4 * N_RESERVED, "vocab too small: {size}");
        Vocab { size }
    }

    /// Number of content (non-reserved) ids.
    pub fn n_content(&self) -> usize {
        self.size - N_RESERVED
    }

    /// Content token id from a dense index in [0, n_content).
    pub fn content(&self, idx: usize) -> i32 {
        debug_assert!(idx < self.n_content());
        (N_RESERVED + idx) as i32
    }

    /// The "medical" domain: the first quarter of content ids (narrow).
    pub fn medical_domain(&self) -> std::ops::Range<usize> {
        0..self.n_content() / 4
    }

    /// Instruction vocab (second quarter) / response vocab (third quarter).
    pub fn instruct_prompt_domain(&self) -> std::ops::Range<usize> {
        self.n_content() / 4..self.n_content() / 2
    }

    pub fn instruct_response_domain(&self) -> std::ops::Range<usize> {
        self.n_content() / 2..3 * self.n_content() / 4
    }

    /// Chat topics: k disjoint slices of the last quarter.
    pub fn chat_topic_domain(&self, topic: usize, n_topics: usize) -> std::ops::Range<usize> {
        let lo = 3 * self.n_content() / 4;
        let width = (self.n_content() - lo) / n_topics;
        let start = lo + topic * width;
        start..start + width
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn domains_are_disjoint() {
        let v = Vocab::new(512);
        let med = v.medical_domain();
        let ip = v.instruct_prompt_domain();
        let ir = v.instruct_response_domain();
        assert!(med.end <= ip.start);
        assert!(ip.end <= ir.start);
        let c0 = v.chat_topic_domain(0, 4);
        let c1 = v.chat_topic_domain(1, 4);
        assert!(ir.end <= c0.start);
        assert!(c0.end <= c1.start);
        assert!(c1.end <= v.n_content());
    }

    #[test]
    fn content_ids_above_reserved() {
        let v = Vocab::new(512);
        assert_eq!(v.content(0), N_RESERVED as i32);
        assert_eq!(v.n_content(), 512 - N_RESERVED);
        assert!(v.content(v.n_content() - 1) < 512);
    }

    #[test]
    #[should_panic]
    fn tiny_vocab_rejected() {
        Vocab::new(16);
    }
}
