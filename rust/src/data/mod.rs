//! Data substrate: synthetic corpora, batching, and the streaming
//! prefetch pipeline.
//!
//! The paper finetunes on three real corpora (Clinical Guidelines, Evol
//! code-instructions, ultrachat). None are available in this offline
//! environment, so `corpus.rs` generates seeded synthetic equivalents that
//! exercise the same code paths (DESIGN.md §Substitutions): a narrow-domain
//! Markov corpus (medical), instruction→response pairs with response-only
//! loss (instruct), and multi-turn topic-coherent dialogues (chat).

pub mod batcher;
pub mod corpus;
pub mod pipeline;
pub mod vocab;

pub use batcher::{Batch, BatchStager, Batcher, GlobalBatch, StagedBatch, StagedMicro};
pub use corpus::{make_dataset, Dataset, Example};
pub use vocab::Vocab;
