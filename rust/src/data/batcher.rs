//! Batch assembly: examples → micro-batches → global batches, plus the
//! device-side staging half of the step pipeline.
//!
//! The coordinator implements the paper's micro/global batch structure
//! (Appendix E tables): a *global* optimizer batch is split into
//! `global/micro` micro-batches whose gradients the trainer accumulates
//! before one Adam application. Epoch order is a seeded shuffle, identical
//! between the baseline and FF runs.
//!
//! [`BatchStager`] is the upload side of the pipelined step engine
//! (`train::engine`): a double buffer of device-resident global batches.
//! While step *N* executes on the device, the stager uploads step *N+1*'s
//! tokens/targets/mask — PJRT uploads are asynchronous, so the copy
//! overlaps the in-flight computation instead of serializing in front of
//! the next dispatch. Byte totals are unchanged (each batch uploads
//! exactly once); only the *when* moves one step earlier. See
//! `docs/step-pipeline.md`.

use std::sync::Arc;

use anyhow::Result;

use crate::data::corpus::Example;
use crate::runtime::{upload_f32_opt, upload_i32_opt, Runtime, TransferMeter};
use crate::util::rng::Rng;

/// One device-shaped batch: flattened `[b, t]` row-major buffers.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub b: usize,
    pub t: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
}

impl Batch {
    pub fn from_examples(examples: &[&Example]) -> Batch {
        assert!(!examples.is_empty());
        let t = examples[0].mask.len();
        let b = examples.len();
        let mut batch = Batch {
            b,
            t,
            tokens: Vec::with_capacity(b * t),
            targets: Vec::with_capacity(b * t),
            mask: Vec::with_capacity(b * t),
        };
        for ex in examples {
            assert_eq!(ex.mask.len(), t, "ragged example lengths");
            batch.tokens.extend_from_slice(ex.tokens());
            batch.targets.extend_from_slice(ex.targets());
            batch.mask.extend_from_slice(&ex.mask);
        }
        batch
    }

    /// Non-pad target tokens — the denominator in FLOPs/token accounting.
    pub fn loss_tokens(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }

    /// Total token positions (padding included) — what the forward pass
    /// actually computes over, hence what FLOPs accounting charges.
    pub fn total_tokens(&self) -> usize {
        self.b * self.t
    }
}

/// One optimizer step's worth of data.
#[derive(Debug, Clone)]
pub struct GlobalBatch {
    pub micro: Vec<Batch>,
}

impl GlobalBatch {
    pub fn total_tokens(&self) -> usize {
        self.micro.iter().map(|m| m.total_tokens()).sum()
    }
}

/// Deterministic epoch iterator over a dataset split.
pub struct Batcher<'a> {
    examples: &'a [Example],
    micro_batch: usize,
    global_batch: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    rng: Rng,
}

impl<'a> Batcher<'a> {
    pub fn new(
        examples: &'a [Example],
        micro_batch: usize,
        global_batch: usize,
        seed: u64,
    ) -> Batcher<'a> {
        assert!(global_batch % micro_batch == 0, "global must be a multiple of micro");
        assert!(
            examples.len() >= global_batch,
            "dataset smaller than one global batch"
        );
        let mut b = Batcher {
            examples,
            micro_batch,
            global_batch,
            order: (0..examples.len()).collect(),
            cursor: 0,
            epoch: 0,
            rng: Rng::new(seed ^ 0xba7c4),
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    pub fn steps_per_epoch(&self) -> usize {
        self.examples.len() / self.global_batch
    }

    /// Next global batch; rolls into a fresh shuffled epoch when exhausted
    /// (partial trailing batches are dropped, like the paper's loader).
    pub fn next_global(&mut self) -> GlobalBatch {
        if self.cursor + self.global_batch > self.examples.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let idxs = &self.order[self.cursor..self.cursor + self.global_batch];
        self.cursor += self.global_batch;
        let micro = idxs
            .chunks(self.micro_batch)
            .map(|chunk| {
                let refs: Vec<&Example> =
                    chunk.iter().map(|&i| &self.examples[i]).collect();
                Batch::from_examples(&refs)
            })
            .collect();
        GlobalBatch { micro }
    }
}

/// One micro-batch resident on the device: the three input buffers every
/// `grad_step`/`eval_loss` dispatch consumes, uploaded by [`BatchStager`].
pub struct StagedMicro {
    pub tokens: xla::PjRtBuffer,
    pub targets: xla::PjRtBuffer,
    pub mask: xla::PjRtBuffer,
}

/// One optimizer step's worth of device-resident input data, plus the
/// host-side scalars the coordinator still needs (FLOPs charging).
pub struct StagedBatch {
    pub micro: Vec<StagedMicro>,
    /// Σ b·t over micro-batches (what the forward pass computes over).
    pub total_tokens: usize,
}

impl StagedBatch {
    /// Upload every micro-batch of `global` (tokens/targets/mask each).
    pub fn upload(rt: &Runtime, global: &GlobalBatch) -> Result<StagedBatch> {
        Self::upload_metered(rt, None, global)
    }

    /// [`StagedBatch::upload`] that additionally tallies every uploaded
    /// byte into the owning run's exact [`TransferMeter`].
    pub fn upload_metered(
        rt: &Runtime,
        meter: Option<&TransferMeter>,
        global: &GlobalBatch,
    ) -> Result<StagedBatch> {
        let mut micro = Vec::with_capacity(global.micro.len());
        for mb in &global.micro {
            micro.push(StagedMicro {
                tokens: upload_i32_opt(rt, meter, &mb.tokens, &[mb.b, mb.t])?,
                targets: upload_i32_opt(rt, meter, &mb.targets, &[mb.b, mb.t])?,
                mask: upload_f32_opt(rt, meter, &mb.mask, &[mb.b, mb.t])?,
            });
        }
        Ok(StagedBatch { micro, total_tokens: global.total_tokens() })
    }
}

/// Double-buffered batch staging (see module docs): holds at most one
/// pre-uploaded global batch. The step engine calls
/// [`BatchStager::take_or_stage`] at the top of each step (hit in steady
/// state — the batch was uploaded while the previous step executed) and
/// [`BatchStager::prefetch`] right after dispatching, while the device is
/// busy.
pub struct BatchStager {
    rt: Arc<Runtime>,
    /// The owning run's exact per-run meter, if any (staged uploads are
    /// that run's traffic, whichever step they overlap).
    meter: Option<Arc<TransferMeter>>,
    staged: Option<StagedBatch>,
    /// Steps that found their batch already staged (pipeline hit rate).
    hits: u64,
    misses: u64,
}

impl BatchStager {
    pub fn new(rt: &Arc<Runtime>) -> BatchStager {
        BatchStager { rt: Arc::clone(rt), meter: None, staged: None, hits: 0, misses: 0 }
    }

    /// A stager whose uploads also tally into the owning run's exact
    /// [`TransferMeter`] (what `StepEngine` constructs).
    pub fn with_meter(rt: &Arc<Runtime>, meter: &Arc<TransferMeter>) -> BatchStager {
        let mut s = Self::new(rt);
        s.meter = Some(Arc::clone(meter));
        s
    }

    /// The batch for the step starting now: the prefetched one when
    /// available (steady state), otherwise staged on the spot from `next`
    /// (first step, or a consumer that skipped `prefetch`).
    pub fn take_or_stage(
        &mut self,
        mut next: impl FnMut() -> GlobalBatch,
    ) -> Result<StagedBatch> {
        match self.staged.take() {
            Some(b) => {
                self.hits += 1;
                Ok(b)
            }
            None => {
                self.misses += 1;
                StagedBatch::upload_metered(&self.rt, self.meter.as_deref(), &next())
            }
        }
    }

    /// Stage the *next* step's batch now, so its upload overlaps the
    /// current step's in-flight device work. No-op if a batch is already
    /// staged.
    pub fn prefetch(&mut self, mut next: impl FnMut() -> GlobalBatch) -> Result<()> {
        if self.staged.is_none() {
            self.staged =
                Some(StagedBatch::upload_metered(&self.rt, self.meter.as_deref(), &next())?);
        }
        Ok(())
    }

    /// Whether a batch is currently staged ahead.
    pub fn is_primed(&self) -> bool {
        self.staged.is_some()
    }

    /// (steps served from the prefetched slot, steps that had to upload
    /// inline).
    pub fn hit_counts(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

/// Chunk a fixed evaluation split into `eval_batch`-sized batches, padding
/// the tail by repeating the first examples (extra rows get zero masks so
/// they do not contribute to the mean — handled by the caller via weights).
pub fn eval_batches(examples: &[Example], eval_batch: usize) -> Vec<(Batch, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < examples.len() {
        let end = (i + eval_batch).min(examples.len());
        let real = end - i;
        let mut refs: Vec<&Example> = examples[i..end].iter().collect();
        let mut fill = 0;
        while refs.len() < eval_batch {
            refs.push(&examples[fill % examples.len()]);
            fill += 1;
        }
        let mut batch = Batch::from_examples(&refs);
        // zero the mask of padding rows so the batch loss ignores them
        for row in real..eval_batch {
            for m in &mut batch.mask[row * batch.t..(row + 1) * batch.t] {
                *m = 0.0;
            }
        }
        out.push((batch, real));
        i = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::make_dataset;

    fn examples() -> Vec<Example> {
        make_dataset("medical", 512, 64, 64, 8, 4, 1).unwrap().train
    }

    #[test]
    fn batch_layout_row_major() {
        let exs = examples();
        let refs: Vec<&Example> = exs[..4].iter().collect();
        let b = Batch::from_examples(&refs);
        assert_eq!((b.b, b.t), (4, 64));
        assert_eq!(&b.tokens[..64], exs[0].tokens());
        assert_eq!(&b.tokens[64..128], exs[1].tokens());
        assert_eq!(&b.targets[..64], exs[0].targets());
    }

    #[test]
    fn global_batch_structure() {
        let exs = examples();
        let mut bt = Batcher::new(&exs, 8, 32, 0);
        let g = bt.next_global();
        assert_eq!(g.micro.len(), 4);
        assert!(g.micro.iter().all(|m| m.b == 8));
        assert_eq!(g.total_tokens(), 32 * 64);
        assert_eq!(bt.steps_per_epoch(), 2);
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let exs = examples();
        let collect = |seed| {
            let mut bt = Batcher::new(&exs, 8, 32, seed);
            (0..6).map(|_| bt.next_global().micro[0].tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(collect(5), collect(5));
        assert_ne!(collect(5), collect(6));
        let mut bt = Batcher::new(&exs, 8, 32, 5);
        for _ in 0..2 {
            bt.next_global();
        }
        assert_eq!(bt.epoch(), 0);
        bt.next_global();
        assert_eq!(bt.epoch(), 1);
    }

    #[test]
    fn every_example_seen_once_per_epoch() {
        let exs = examples();
        let mut bt = Batcher::new(&exs, 8, 32, 2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2 {
            for m in bt.next_global().micro {
                for row in 0..m.b {
                    seen.insert(m.tokens[row * m.t..(row + 1) * m.t].to_vec());
                }
            }
        }
        assert_eq!(seen.len(), 64); // all distinct examples covered
    }

    #[test]
    fn eval_batches_cover_and_pad() {
        let exs = examples();
        let chunks = eval_batches(&exs[..10], 8);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].1, 8);
        assert_eq!(chunks[1].1, 2);
        // padded rows have zero mask
        let (tail, real) = &chunks[1];
        for row in *real..8 {
            assert!(tail.mask[row * tail.t..(row + 1) * tail.t].iter().all(|&m| m == 0.0));
        }
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn global_not_multiple_of_micro_panics() {
        let exs = examples();
        Batcher::new(&exs, 8, 12, 0);
    }

    #[test]
    fn stager_double_buffers_without_extra_uploads() {
        let rt = Runtime::cpu().unwrap();
        let exs = examples();
        let mut bt = Batcher::new(&exs, 8, 16, 4);
        let mut stager = BatchStager::new(&rt);
        assert!(!stager.is_primed());

        // first step: nothing staged — uploads inline (miss)
        let before = rt.stats.snapshot();
        let b0 = stager.take_or_stage(|| bt.next_global()).unwrap();
        let d0 = rt.stats.snapshot().since(&before);
        assert_eq!(b0.micro.len(), 2);
        assert_eq!(b0.total_tokens, 16 * 64);
        assert_eq!(d0.uploads, 3 * 2, "tokens/targets/mask per micro");

        // prefetch fills the slot once; a second prefetch is free
        let before = rt.stats.snapshot();
        stager.prefetch(|| bt.next_global()).unwrap();
        assert!(stager.is_primed());
        stager.prefetch(|| bt.next_global()).unwrap();
        let d1 = rt.stats.snapshot().since(&before);
        assert_eq!(d1.uploads, 3 * 2, "double prefetch must not re-upload");

        // steady state: the staged batch is served with zero uploads
        let before = rt.stats.snapshot();
        let b1 = stager.take_or_stage(|| panic!("staged batch must be served")).unwrap();
        assert_eq!(rt.stats.snapshot().since(&before).uploads, 0);
        assert_eq!(b1.micro.len(), 2);
        assert_eq!(stager.hit_counts(), (1, 1));
    }

    #[test]
    fn staged_batch_bytes_match_host_batch() {
        let rt = Runtime::cpu().unwrap();
        let exs = examples();
        let mut bt = Batcher::new(&exs, 8, 32, 7);
        let g = bt.next_global();
        let want: u64 = g
            .micro
            .iter()
            .map(|m| (m.tokens.len() + m.targets.len() + m.mask.len()) as u64 * 4)
            .sum();
        let before = rt.stats.snapshot();
        let staged = StagedBatch::upload(&rt, &g).unwrap();
        let d = rt.stats.snapshot().since(&before);
        assert_eq!(d.uploaded_bytes, want, "prefetch moves the same bytes");
        assert_eq!(staged.total_tokens, g.total_tokens());
    }
}
