//! Run metrics: step records, loss curves, wall-clock timers, and report
//! writers (JSON via the in-repo codec + aligned plain text for the
//! paper-figure reports under reports/).

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// What kind of update produced a step record (paper Fig 4 colors).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// Regular Adam step (red dots).
    Sgd,
    /// FF simulated step (green dots).
    FastForward,
}

#[derive(Debug, Clone)]
pub struct StepRecord {
    /// Monotone step index counting SGD + simulated steps (Fig 4 x-axis).
    pub step: usize,
    pub kind: StepKind,
    pub loss: f32,
    /// Cumulative chargeable FLOPs after this step.
    pub flops: u64,
    /// Elapsed train seconds after this step.
    pub seconds: f64,
}

/// Accumulates the full trajectory of one training run.
#[derive(Debug, Default)]
pub struct RunLog {
    pub records: Vec<StepRecord>,
    /// (test loss, step, flops, seconds) measurements.
    pub test_evals: Vec<(f32, usize, u64, f64)>,
}

impl RunLog {
    pub fn push(&mut self, rec: StepRecord) {
        self.records.push(rec);
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    pub fn n_sgd(&self) -> usize {
        self.records.iter().filter(|r| r.kind == StepKind::Sgd).count()
    }

    pub fn n_ff(&self) -> usize {
        self.records.iter().filter(|r| r.kind == StepKind::FastForward).count()
    }

    pub fn to_json(&self) -> Json {
        let recs: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj()
                    .set("step", r.step)
                    .set("kind", match r.kind {
                        StepKind::Sgd => "sgd",
                        StepKind::FastForward => "ff",
                    })
                    .set("loss", r.loss as f64)
                    .set("flops", r.flops as f64)
                    .set("seconds", r.seconds)
            })
            .collect();
        let evals: Vec<Json> = self
            .test_evals
            .iter()
            .map(|(l, s, f, t)| {
                Json::obj()
                    .set("loss", *l as f64)
                    .set("step", *s)
                    .set("flops", *f as f64)
                    .set("seconds", *t)
            })
            .collect();
        Json::obj().set("records", Json::Arr(recs)).set("test_evals", Json::Arr(evals))
    }
}

/// Wall-clock stopwatch that can exclude measurement-only sections
/// (test-set evals don't count as train time, matching the paper).
#[derive(Debug)]
pub struct TrainTimer {
    started: Instant,
    excluded: f64,
    pause_at: Option<Instant>,
}

impl TrainTimer {
    pub fn start() -> TrainTimer {
        TrainTimer { started: Instant::now(), excluded: 0.0, pause_at: None }
    }

    pub fn pause(&mut self) {
        assert!(self.pause_at.is_none(), "already paused");
        self.pause_at = Some(Instant::now());
    }

    pub fn resume(&mut self) {
        let p = self.pause_at.take().expect("not paused");
        self.excluded += p.elapsed().as_secs_f64();
    }

    /// Credit train seconds carried from before this timer started — a
    /// negative exclusion, used when a parked run resumes so its summary
    /// reports whole-run train time, not just the post-resume tail.
    pub fn credit(&mut self, seconds: f64) {
        self.excluded -= seconds;
    }

    /// Train seconds so far, net of excluded sections.
    pub fn elapsed(&self) -> f64 {
        let gross = self.started.elapsed().as_secs_f64();
        let pending = self.pause_at.map(|p| p.elapsed().as_secs_f64()).unwrap_or(0.0);
        gross - self.excluded - pending
    }
}

/// Write a report as both pretty JSON and aligned text under `reports/`.
pub fn write_report(dir: &Path, name: &str, json: &Json, text: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut jf = std::fs::File::create(dir.join(format!("{name}.json")))?;
    jf.write_all(json.to_string_pretty().as_bytes())?;
    let mut tf = std::fs::File::create(dir.join(format!("{name}.txt")))?;
    tf.write_all(text.as_bytes())?;
    crate::info!("wrote reports/{name}.{{json,txt}}");
    Ok(())
}

/// Simple fixed-width table builder for the text reports.
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> TextTable {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(widths.iter())
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runlog_counts_kinds() {
        let mut log = RunLog::default();
        log.push(StepRecord { step: 0, kind: StepKind::Sgd, loss: 2.0, flops: 10, seconds: 0.1 });
        log.push(StepRecord { step: 1, kind: StepKind::FastForward, loss: 1.9, flops: 12, seconds: 0.2 });
        log.push(StepRecord { step: 2, kind: StepKind::FastForward, loss: 1.8, flops: 14, seconds: 0.3 });
        assert_eq!(log.n_sgd(), 1);
        assert_eq!(log.n_ff(), 2);
        assert_eq!(log.last_loss(), Some(1.8));
        let j = log.to_json();
        assert_eq!(j.get("records").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("records").idx(1).get("kind").as_str(), Some("ff"));
    }

    #[test]
    fn timer_excludes_paused_sections() {
        let mut t = TrainTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(30));
        t.pause();
        std::thread::sleep(std::time::Duration::from_millis(50));
        t.resume();
        let e = t.elapsed();
        assert!(e >= 0.025 && e < 0.06, "elapsed {e}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["model", "saved%"]);
        t.row(&["ff-tiny".into(), "63.0".into()]);
        t.row(&["ff-large".into(), "41.5".into()]);
        let s = t.render();
        assert!(s.contains("model"));
        assert!(s.lines().count() == 4);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn write_report_creates_files() {
        let dir = std::env::temp_dir().join(format!("ffrep-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_report(&dir, "t", &Json::obj().set("a", 1i64), "hello").unwrap();
        assert!(dir.join("t.json").exists());
        assert_eq!(std::fs::read_to_string(dir.join("t.txt")).unwrap(), "hello");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
