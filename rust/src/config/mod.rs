//! Configuration system: model architectures, training hyper-parameters
//! (paper Appendix E, Tables 1–3), task definitions, and FF schedules.
//!
//! `ModelConfig` mirrors `python/compile/configs.py` exactly — the runtime
//! cross-checks the derived parameter spec against every artifact's
//! manifest, so a drift between the two definitions fails loudly at load.

pub mod presets;

use crate::util::json::Json;

/// Which parameters train (mirrors `configs.TRAIN_MODES`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrainMode {
    Lora,
    Dora,
    FullAttn,
    FullAll,
}

impl TrainMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            TrainMode::Lora => "lora",
            TrainMode::Dora => "dora",
            TrainMode::FullAttn => "full_attn",
            TrainMode::FullAll => "full_all",
        }
    }

    pub fn from_str(s: &str) -> anyhow::Result<TrainMode> {
        Ok(match s {
            "lora" => TrainMode::Lora,
            "dora" => TrainMode::Dora,
            "full_attn" => TrainMode::FullAttn,
            "full_all" => TrainMode::FullAll,
            other => anyhow::bail!("unknown train mode '{other}'"),
        })
    }

    pub fn is_low_rank(&self) -> bool {
        matches!(self, TrainMode::Lora | TrainMode::Dora)
    }
}

/// Architecture of one GPT-style model (mirror of python `ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub micro_batch: usize,
    pub eval_batch: usize,
}

impl ModelConfig {
    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total base parameter count (must equal python `n_params`).
    pub fn n_params(&self) -> usize {
        let (d, v, t) = (self.d_model, self.vocab_size, self.seq_len);
        let per_layer = 4 * d * d + 2 * d * self.d_ff() + 4 * d;
        v * d + t * d + self.n_layers * per_layer + 2 * d + d * v
    }

    pub fn from_manifest(cfg: &Json) -> anyhow::Result<ModelConfig> {
        let need = |k: &str| -> anyhow::Result<usize> {
            cfg.get(k)
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("manifest config missing '{k}'"))
        };
        Ok(ModelConfig {
            name: cfg
                .get("model")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest config missing 'model'"))?
                .to_string(),
            vocab_size: need("vocab_size")?,
            d_model: need("d_model")?,
            n_layers: need("n_layers")?,
            n_heads: need("n_heads")?,
            seq_len: need("seq_len")?,
            micro_batch: need("micro_batch")?,
            eval_batch: need("eval_batch")?,
        })
    }
}

/// One artifact = (model, mode, rank); mirrors python `ArtifactConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactConfig {
    pub model: ModelConfig,
    pub train_mode: TrainMode,
    pub lora_rank: usize,
    pub lora_alpha: f32,
    pub use_pallas: bool,
}

impl ArtifactConfig {
    pub fn key(&self) -> String {
        let mut parts = vec![self.model.name.clone(), self.train_mode.as_str().to_string()];
        if self.train_mode.is_low_rank() {
            parts.push(format!("r{}", self.lora_rank));
        }
        if self.use_pallas {
            parts.push("pallas".to_string());
        }
        parts.join("_")
    }

    pub fn lora_scale(&self) -> f32 {
        self.lora_alpha / self.lora_rank as f32
    }
}

/// Adam hyper-parameters (fixed across the paper).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Which trigger policy decides *when* to Fast Forward (`crate::ff::policy`).
///
/// `Interval` is the paper's fixed/adaptive T_interval controller and the
/// default — bit-identical to the pre-policy `FfController`. The other two
/// come from the paper's closing analysis: fire when the tiny-val loss
/// slope flattens, or when consecutive Δ_W directions align.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FfPolicyKind {
    #[default]
    Interval,
    LossSlope,
    Cosine,
}

impl FfPolicyKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FfPolicyKind::Interval => "interval",
            FfPolicyKind::LossSlope => "loss_slope",
            FfPolicyKind::Cosine => "cosine",
        }
    }

    pub fn from_str(s: &str) -> anyhow::Result<FfPolicyKind> {
        Ok(match s {
            "interval" => FfPolicyKind::Interval,
            "loss_slope" => FfPolicyKind::LossSlope,
            "cosine" => FfPolicyKind::Cosine,
            other => anyhow::bail!("unknown FF policy '{other}'"),
        })
    }

    pub const ALL: [FfPolicyKind; 3] =
        [FfPolicyKind::Interval, FfPolicyKind::LossSlope, FfPolicyKind::Cosine];
}

/// Which optimizer backend steps the run (`train::engine`).
///
/// `Adam` is the baseline donated `adam_apply` chain. `Loft` is the
/// LoFT-style variant (PAPERS.md, "low-rank that behaves like full
/// fine-tuning"): the same chain, plus a periodic optimizer-state
/// realignment — after every FF stage the second moments are decayed
/// (`m *= decay`, `v *= decay²`) so stale curvature from before the
/// extrapolation jump does not mis-scale the next steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptimBackend {
    #[default]
    Adam,
    Loft,
}

impl OptimBackend {
    pub fn as_str(&self) -> &'static str {
        match self {
            OptimBackend::Adam => "adam",
            OptimBackend::Loft => "loft",
        }
    }

    pub fn from_str(s: &str) -> anyhow::Result<OptimBackend> {
        Ok(match s {
            "adam" => OptimBackend::Adam,
            "loft" => OptimBackend::Loft,
            other => anyhow::bail!("unknown optimizer backend '{other}'"),
        })
    }
}

/// Fast Forward schedule (paper §3 + §5.1).
#[derive(Debug, Clone, PartialEq)]
pub struct FfConfig {
    /// Run FF at all (false = vanilla Adam SGD baseline).
    pub enabled: bool,
    /// Adam steps between FF stages (paper: T_interval = 6).
    pub t_interval: usize,
    /// Plain Adam steps before the first FF stage.
    pub warmup_steps: usize,
    /// Maximum simulated steps per stage (safety bound; paper Fig 10 probes 100).
    pub max_tau: usize,
    /// Stop training after this many consecutive FF stages fail to improve
    /// the tiny-val loss at τ=1 (paper §5.1 uses 3); None = never.
    pub convergence_patience: Option<usize>,
    /// Adaptive T_interval (paper §7 future work): shrink the interval when
    /// FF stages are long, grow it when they fizzle.
    pub adaptive_interval: bool,
    /// Tiny validation set size (paper: 32 examples).
    pub val_examples: usize,
    /// A simulated step must improve val loss by at least this *relative*
    /// amount to continue the stage. The paper stops on any increase
    /// (threshold 0); our default 1e-3 guards against overfitting the
    /// 32-sample val set at this substrate's compressed scale (the paper's
    /// §7 notes the risk; DESIGN.md §Substitutions documents the choice).
    pub min_rel_improvement: f32,
    /// Trigger policy (`crate::ff::policy`): `Interval` (default,
    /// bit-identical to the pre-policy controller), `LossSlope`, `Cosine`.
    pub policy: FfPolicyKind,
    /// LossSlope: number of per-step tiny-val losses in the slope window.
    pub slope_window: usize,
    /// LossSlope: fire when the windowed relative improvement per step
    /// drops below this (the loss curve has flattened).
    pub slope_threshold: f32,
    /// Cosine: fire when consecutive Δ_W directions' cosine similarity
    /// reaches this (updates have locked onto a consistent direction).
    pub cosine_threshold: f64,
}

impl FfConfig {
    /// Stable fingerprint over every scheduling-relevant field, stamped
    /// into `train::checkpoint::ParkState` so a resume under an edited
    /// `FfConfig` fails loudly instead of silently running with a
    /// snapshot taken under different rules (e.g. an `interval` outside
    /// the new `[1, 4·t_interval]` clamp).
    pub fn fingerprint(&self) -> String {
        format!(
            "v1|{}|{}|{}|{}|{:?}|{}|{}|{}|{}|{}|{}|{}",
            self.enabled,
            self.t_interval,
            self.warmup_steps,
            self.max_tau,
            self.convergence_patience,
            self.adaptive_interval,
            self.val_examples,
            self.min_rel_improvement,
            self.policy.as_str(),
            self.slope_window,
            self.slope_threshold,
            self.cosine_threshold,
        )
    }
}

impl Default for FfConfig {
    fn default() -> Self {
        FfConfig {
            enabled: true,
            t_interval: 6,
            warmup_steps: 6,
            max_tau: 200,
            convergence_patience: None,
            adaptive_interval: false,
            val_examples: 32,
            min_rel_improvement: 1e-3,
            policy: FfPolicyKind::Interval,
            slope_window: 8,
            slope_threshold: 2e-2,
            cosine_threshold: 0.9,
        }
    }
}

/// Full training-run description (what `Trainer::new` consumes).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact key, e.g. "ff-tiny_lora_r8".
    pub artifact: String,
    /// Task name: medical | instruct | chat | pile (pretrain mix).
    pub task: String,
    pub lr: f32,
    pub global_batch: usize,
    /// Number of optimizer steps (or epochs via `epochs`).
    pub max_steps: usize,
    pub seed: u64,
    pub ff: FfConfig,
    pub adam: AdamConfig,
    /// Optimizer backend: baseline Adam, or the LoFT-style realigning
    /// variant (see [`OptimBackend`]).
    pub backend: OptimBackend,
    /// LoFT realignment decay applied to the Adam moments after each FF
    /// stage (`m *= decay`, `v *= decay²`). Only read when
    /// `backend == OptimBackend::Loft`.
    pub loft_decay: f32,
    /// Training examples to generate for the corpus.
    pub train_examples: usize,
    /// Held-out test examples (paper: 1K).
    pub test_examples: usize,
}

impl TrainConfig {
    /// JSON round-trip used by `reports/` and checkpoint metadata.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("artifact", self.artifact.as_str())
            .set("task", self.task.as_str())
            .set("lr", self.lr as f64)
            .set("global_batch", self.global_batch)
            .set("max_steps", self.max_steps)
            .set("seed", self.seed as i64)
            .set("backend", self.backend.as_str())
            .set("loft_decay", self.loft_decay as f64)
            .set("train_examples", self.train_examples)
            .set("test_examples", self.test_examples)
            .set(
                "ff",
                Json::obj()
                    .set("enabled", self.ff.enabled)
                    .set("t_interval", self.ff.t_interval)
                    .set("warmup_steps", self.ff.warmup_steps)
                    .set("max_tau", self.ff.max_tau)
                    .set("adaptive_interval", self.ff.adaptive_interval)
                    .set("val_examples", self.ff.val_examples)
                    .set("policy", self.ff.policy.as_str()),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_params_matches_python_values() {
        // Golden values printed by `python -m compile.aot` (index.json).
        let tiny = presets::model("ff-tiny").unwrap();
        assert_eq!(tiny.n_params(), 168_576);
        let xl = presets::model("ff-xl").unwrap();
        assert!(xl.n_params() > 80_000_000, "{}", xl.n_params());
    }

    #[test]
    fn artifact_keys_match_python() {
        let ac = ArtifactConfig {
            model: presets::model("ff-tiny").unwrap(),
            train_mode: TrainMode::Lora,
            lora_rank: 8,
            lora_alpha: 16.0,
            use_pallas: false,
        };
        assert_eq!(ac.key(), "ff-tiny_lora_r8");
        let ac2 = ArtifactConfig { train_mode: TrainMode::FullAttn, ..ac.clone() };
        assert_eq!(ac2.key(), "ff-tiny_full_attn");
        let ac3 = ArtifactConfig { use_pallas: true, ..ac };
        assert_eq!(ac3.key(), "ff-tiny_lora_r8_pallas");
    }

    #[test]
    fn train_mode_round_trip() {
        for m in [TrainMode::Lora, TrainMode::Dora, TrainMode::FullAttn, TrainMode::FullAll] {
            assert_eq!(TrainMode::from_str(m.as_str()).unwrap(), m);
        }
        assert!(TrainMode::from_str("bogus").is_err());
    }

    #[test]
    fn ff_defaults_match_paper() {
        let ff = FfConfig::default();
        assert_eq!(ff.t_interval, 6); // paper §3
        assert_eq!(ff.val_examples, 32); // paper §4
    }
}
