//! Preset registry: the model ladder (DESIGN.md §Substitutions) and the
//! paper's per-task hyper-parameter tables (Appendix E, Tables 1–3).
//!
//! Paper model ↔ substitute: Pythia-1.4B ↔ ff-tiny, Pythia-2.8B ↔ ff-small,
//! Pythia-6.9B ↔ ff-medium, Llama-3-8B ↔ ff-large. The learning rates,
//! batch *ratios* and LoRA ranks follow the paper tables; absolute batch
//! sizes are scaled to a single-core CPU testbed (global 32 vs the paper's
//! 64–512) while keeping the paper's micro:global structure.

use super::{AdamConfig, FfConfig, ModelConfig, OptimBackend, TrainConfig};

/// The four grid models + the e2e-only xl config (must mirror python).
pub fn model(name: &str) -> anyhow::Result<ModelConfig> {
    let m = |name: &str, v, d, l, h, t, mb| ModelConfig {
        name: name.to_string(),
        vocab_size: v,
        d_model: d,
        n_layers: l,
        n_heads: h,
        seq_len: t,
        micro_batch: mb,
        eval_batch: 8,
    };
    Ok(match name {
        "ff-tiny" => m("ff-tiny", 512, 64, 2, 2, 64, 8),
        "ff-small" => m("ff-small", 1024, 128, 4, 4, 64, 8),
        "ff-medium" => m("ff-medium", 2048, 256, 6, 8, 128, 4),
        "ff-large" => m("ff-large", 4096, 384, 8, 8, 128, 2),
        "ff-xl" => m("ff-xl", 8192, 768, 12, 12, 256, 1),
        other => anyhow::bail!("unknown model '{other}'"),
    })
}

pub const GRID_MODELS: [&str; 4] = ["ff-tiny", "ff-small", "ff-medium", "ff-large"];
pub const TASKS: [&str; 3] = ["medical", "instruct", "chat"];

/// Paper model each substitute stands in for (report labelling).
pub fn paper_model(name: &str) -> &'static str {
    match name {
        "ff-tiny" => "Pythia-1.4B",
        "ff-small" => "Pythia-2.8B",
        "ff-medium" => "Pythia-6.9B",
        "ff-large" => "Llama-3-8B",
        _ => "(e2e only)",
    }
}

/// Task hyper-parameters from paper Tables 1–3, scaled to this testbed.
///
/// Paper values — medical: lr 4e-5, global 128, r 8; instruct: lr 5e-6,
/// global 64, r 8; chat: lr 2e-5, global 512, r 64. We keep the lr *ordering*
/// and the rank per task, bump lr magnitude for the tiny substitute models
/// (whose widths are ~100× smaller than Pythia's), and scale global batch to
/// 32 (16 for chat's long sequences) so a grid cell runs in minutes on one
/// core. See EXPERIMENTS.md for the mapping table.
#[derive(Debug, Clone)]
pub struct TaskPreset {
    pub task: &'static str,
    pub lr: f32,
    pub global_batch: usize,
    pub lora_rank: usize,
    /// Training-corpus examples (paper: 37K / 109K / 208K → scaled).
    pub train_examples: usize,
}

pub fn task_preset(task: &str) -> anyhow::Result<TaskPreset> {
    Ok(match task {
        // paper Table 1 (medical): the highest lr of the three tasks.
        "medical" => TaskPreset { task: "medical", lr: 1e-3, global_batch: 32, lora_rank: 8, train_examples: 2048 },
        // paper Table 2 (instruct): the lowest lr.
        "instruct" => TaskPreset { task: "instruct", lr: 2.5e-4, global_batch: 32, lora_rank: 8, train_examples: 3072 },
        // paper Table 3 (chat): mid lr, large batch, rank 64.
        "chat" => TaskPreset { task: "chat", lr: 5e-4, global_batch: 16, lora_rank: 64, train_examples: 4096 },
        // pretraining mix (manufactures W0 for finetuning runs).
        "pile" => TaskPreset { task: "pile", lr: 3e-3, global_batch: 32, lora_rank: 8, train_examples: 4096 },
        other => anyhow::bail!("unknown task '{other}'"),
    })
}

/// Build a full `TrainConfig` for (artifact key, task), mirroring the paper's
/// training/eval protocol: 5 epochs baseline, 1K held-out test examples,
/// 32-sample tiny validation set.
pub fn train_config(artifact: &str, task: &str, epochs: usize) -> anyhow::Result<TrainConfig> {
    let tp = task_preset(task)?;
    let steps_per_epoch = tp.train_examples / tp.global_batch;
    Ok(TrainConfig {
        artifact: artifact.to_string(),
        task: task.to_string(),
        lr: tp.lr,
        global_batch: tp.global_batch,
        max_steps: epochs * steps_per_epoch,
        seed: 0x5eed,
        ff: FfConfig::default(),
        adam: AdamConfig::default(),
        backend: OptimBackend::default(),
        loft_decay: 0.5,
        train_examples: tp.train_examples,
        test_examples: 1000,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_resolve() {
        for name in GRID_MODELS.iter().chain(["ff-xl"].iter()) {
            let m = model(name).unwrap();
            assert_eq!(m.name, *name);
            assert_eq!(m.d_model % m.n_heads, 0);
        }
        assert!(model("nope").is_err());
    }

    #[test]
    fn ladder_is_monotone() {
        let sizes: Vec<usize> = ["ff-tiny", "ff-small", "ff-medium", "ff-large", "ff-xl"]
            .iter()
            .map(|n| model(n).unwrap().n_params())
            .collect();
        for w in sizes.windows(2) {
            assert!(w[0] < w[1], "{sizes:?}");
        }
    }

    #[test]
    fn task_presets_follow_paper_structure() {
        let med = task_preset("medical").unwrap();
        let ins = task_preset("instruct").unwrap();
        let chat = task_preset("chat").unwrap();
        // lr ordering matches Tables 1–3: medical > chat > instruct.
        assert!(med.lr > chat.lr && chat.lr > ins.lr);
        // chat uses rank 64 (Table 3) and the largest corpus + batch ratio.
        assert_eq!(chat.lora_rank, 64);
        assert_eq!(med.lora_rank, 8);
        assert!(chat.train_examples > ins.train_examples);
        assert!(ins.train_examples > med.train_examples);
    }

    #[test]
    fn train_config_epoch_math() {
        let tc = train_config("ff-tiny_lora_r8", "medical", 5).unwrap();
        assert_eq!(tc.max_steps, 5 * (2048 / 32));
        assert_eq!(tc.test_examples, 1000);
        assert_eq!(tc.ff.val_examples, 32);
    }
}
