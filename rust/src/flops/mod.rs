//! Analytic FLOPs accounting — the paper's §4 protocol:
//!
//! > we record the total training time and number of FLOPs from all
//! > computation, including Adam SGD updates, inference on the small
//! > validation set during Fast Forward, and setting model parameters.
//!
//! Convention: forward = 2·N_matmul·tokens (Kaplan et al. 2020); backward
//! = 2× forward (Kaplan/Hoffmann 1:2 fwd:bwd); attention-score FLOPs are
//! included via the 2·T·d per-token term. Adam ≈ 10 flops/param; a FF
//! simulated step costs one val-set forward + |trainable| axpy flops
//! ("setting model parameters").

use crate::config::{ArtifactConfig, TrainMode};
use crate::model::spec;
use crate::runtime::manifest::{LoraOrder, Manifest};

/// Adapter-only cost of one LoRA projection's forward pass under a given
/// contraction order — the exact mirror of
/// `python/compile/contraction.forward_flops` (x: [M,K], A: [K,r],
/// B: [r,N]; base `x·W0` excluded, it is identical under both orders).
pub fn lora_forward_flops(order: LoraOrder, m: usize, k: usize, n: usize, r: usize) -> u64 {
    match order {
        LoraOrder::Factored => 2 * (m * r * (k + n)) as u64,
        LoraOrder::Merged => 2 * (k * r * n) as u64 + 2 * (m * k * n) as u64,
    }
}

/// Adapter backward cost (dA, dB, and the adapter term of dx) — mirror of
/// `python/compile/contraction.backward_flops`.
pub fn lora_backward_flops(order: LoraOrder, m: usize, k: usize, n: usize, r: usize) -> u64 {
    match order {
        LoraOrder::Factored => 2 * (m * r * (3 * k + 2 * n)) as u64,
        LoraOrder::Merged => {
            2 * (m * k * n) as u64 + 4 * (k * r * n) as u64 + 2 * (m * r * (k + n)) as u64
        }
    }
}

/// Exact per-program-call adapter costs, derived from the contraction
/// orders the manifest recorded at emit time. The merged order has a
/// per-call constant (materializing `A·B`), so these are charged per
/// program call, not per token.
#[derive(Debug, Clone, Copy)]
struct LoraFlops {
    /// Tokens per train-program call (micro_batch · seq_len).
    m_train: usize,
    /// Tokens per eval-program call (eval_batch · seq_len).
    m_eval: usize,
    /// Adapter fwd+bwd cost of one train-program call (all projections).
    train_per_call: u64,
    /// Adapter forward cost of one eval-program call.
    eval_per_call: u64,
}

/// Per-model static FLOPs coefficients.
#[derive(Debug, Clone, Copy)]
pub struct FlopsModel {
    /// Matmul params active in a forward pass. Legacy (`for_artifact`)
    /// folds the adapters in; the manifest-exact model keeps base-only and
    /// charges adapters through `lora`.
    pub n_active: usize,
    /// Trainable parameter count (host update / Adam costs).
    pub n_trainable: usize,
    /// Attention quadratic term per token: 2 · T · d_model · n_layers.
    pub attn_per_token: usize,
    /// `Some` ⇒ adapter FLOPs follow the manifest's recorded contraction
    /// orders exactly; `None` ⇒ legacy factored-order approximation.
    lora: Option<LoraFlops>,
}

impl FlopsModel {
    pub fn for_artifact(ac: &ArtifactConfig) -> FlopsModel {
        let m = &ac.model;
        // Matmul (weight) params touched in forward: everything except LN.
        let per_layer = 4 * m.d_model * m.d_model + 2 * m.d_model * m.d_ff();
        let base_matmul =
            m.vocab_size * m.d_model * 2 + m.seq_len * m.d_model + m.n_layers * per_layer;
        let adapters = match ac.train_mode {
            TrainMode::Lora | TrainMode::Dora => spec::n_trainable(ac),
            _ => 0,
        };
        FlopsModel {
            n_active: base_matmul + adapters,
            n_trainable: spec::n_trainable(ac),
            attn_per_token: 2 * m.seq_len * m.d_model * m.n_layers,
            lora: None,
        }
    }

    /// Manifest-exact model: LoRA adapter FLOPs are charged per program
    /// call with the contraction orders the artifact actually emitted
    /// (`grad_step` for training, `eval_loss` for inference), so fig2 /
    /// report savings match the HLO that runs rather than assuming the
    /// factored order. Falls back to the legacy approximation for
    /// artifacts without recorded orders (old manifests, non-LoRA modes —
    /// DoRA's ref kernel has no order choice).
    pub fn for_manifest(man: &Manifest) -> FlopsModel {
        let ac = &man.config;
        let mut fm = Self::for_artifact(ac);
        if ac.train_mode != TrainMode::Lora {
            return fm;
        }
        let (Some(train), Some(eval)) = (
            man.programs.get("grad_step").and_then(|p| p.lora_orders),
            man.programs.get("eval_loss").and_then(|p| p.lora_orders),
        ) else {
            return fm;
        };
        let m = &ac.model;
        let (d, r) = (m.d_model, ac.lora_rank);
        let n_mats = (spec::ADAPTED_MATRICES.len() * m.n_layers) as u64;
        let m_train = m.micro_batch * m.seq_len;
        let m_eval = m.eval_batch * m.seq_len;
        // Base-only forward term; adapters move to the per-call costs.
        fm.n_active -= spec::n_trainable(ac);
        fm.lora = Some(LoraFlops {
            m_train,
            m_eval,
            train_per_call: n_mats
                * (lora_forward_flops(train.forward, m_train, d, d, r)
                    + lora_backward_flops(train.backward, m_train, d, d, r)),
            eval_per_call: n_mats * lora_forward_flops(eval.forward, m_eval, d, d, r),
        });
        fm
    }

    pub fn forward_flops(&self, tokens: usize) -> u64 {
        let base = (2 * self.n_active + self.attn_per_token) as u64 * tokens as u64;
        match self.lora {
            Some(l) => base + tokens.div_ceil(l.m_eval) as u64 * l.eval_per_call,
            None => base,
        }
    }

    /// Base forward + backward at the paper's 1:2 ratio; when the manifest
    /// recorded contraction orders, the adapter part is charged exactly
    /// (per train-program call) instead of through the 1:2 approximation.
    pub fn train_flops(&self, tokens: usize) -> u64 {
        match self.lora {
            Some(l) => {
                let base = 3 * (2 * self.n_active + self.attn_per_token) as u64 * tokens as u64;
                base + tokens.div_ceil(l.m_train) as u64 * l.train_per_call
            }
            None => 3 * self.forward_flops(tokens),
        }
    }

    /// Adapter fwd+bwd cost of one train-program call under `order_*`,
    /// irrespective of what the manifest chose — lets benches report the
    /// savings of the recorded order against the alternative.
    pub fn train_call_flops_for_orders(
        &self,
        ac: &ArtifactConfig,
        fwd: LoraOrder,
        bwd: LoraOrder,
    ) -> u64 {
        let m = &ac.model;
        let (d, r) = (m.d_model, ac.lora_rank);
        let n_mats = (spec::ADAPTED_MATRICES.len() * m.n_layers) as u64;
        let mt = m.micro_batch * m.seq_len;
        n_mats * (lora_forward_flops(fwd, mt, d, d, r) + lora_backward_flops(bwd, mt, d, d, r))
    }

    pub fn adam_flops(&self) -> u64 {
        10 * self.n_trainable as u64
    }

    /// One FF simulated step: apply W += Δ (2 flops/param: mul + add).
    pub fn ff_apply_flops(&self) -> u64 {
        2 * self.n_trainable as u64
    }
}

/// Mutable run counter, accumulated by the trainer.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlopsCounter {
    pub train_fwd_bwd: u64,
    pub adam_updates: u64,
    pub ff_inference: u64,
    pub ff_param_updates: u64,
    pub eval_inference: u64,
}

impl FlopsCounter {
    /// Total chargeable FLOPs under the paper's protocol. Test-set
    /// evaluation (`eval_inference`) is the *measurement*, not the method,
    /// so it is tracked separately and excluded — same as the paper, which
    /// charges only val-set inference performed *during* Fast Forward.
    pub fn total(&self) -> u64 {
        self.train_fwd_bwd + self.adam_updates + self.ff_inference + self.ff_param_updates
    }

    pub fn sgd_step(&mut self, fm: &FlopsModel, tokens: usize) {
        self.train_fwd_bwd += fm.train_flops(tokens);
        self.adam_updates += fm.adam_flops();
    }

    pub fn ff_probe(&mut self, fm: &FlopsModel, val_tokens: usize) {
        self.ff_inference += fm.forward_flops(val_tokens);
        self.ff_param_updates += fm.ff_apply_flops();
    }

    pub fn test_eval(&mut self, fm: &FlopsModel, tokens: usize) {
        self.eval_inference += fm.forward_flops(tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn ac(mode: TrainMode) -> ArtifactConfig {
        ArtifactConfig {
            model: presets::model("ff-tiny").unwrap(),
            train_mode: mode,
            lora_rank: 8,
            lora_alpha: 16.0,
            use_pallas: false,
        }
    }

    #[test]
    fn backward_is_twice_forward() {
        let fm = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        assert_eq!(fm.train_flops(100), 3 * fm.forward_flops(100));
    }

    #[test]
    fn lora_adds_adapter_flops_but_few() {
        let base = FlopsModel::for_artifact(&ac(TrainMode::FullAttn));
        let lora = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        assert!(lora.n_active > base.n_active);
        // adapters are < 10% of the forward cost at rank 8
        assert!((lora.n_active - base.n_active) as f64 / (base.n_active as f64) < 0.10);
    }

    #[test]
    fn ff_probe_is_much_cheaper_than_sgd_step() {
        let fm = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        let mut sgd = FlopsCounter::default();
        sgd.sgd_step(&fm, 32 * 64); // global batch of 32 seqs
        let mut ff = FlopsCounter::default();
        ff.ff_probe(&fm, 32 * 64); // val set of 32 seqs: forward only
        assert!(ff.total() * 2 < sgd.total(), "{} vs {}", ff.total(), sgd.total());
    }

    fn manifest_with_orders(
        ac: &ArtifactConfig,
        train: Option<(LoraOrder, LoraOrder)>,
        eval_fwd: Option<LoraOrder>,
    ) -> Manifest {
        use crate::runtime::manifest::{LoraOrders, ProgramSpec};
        use std::collections::BTreeMap;
        let mk = |orders: Option<LoraOrders>| ProgramSpec {
            file: "x.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
            donated_inputs: vec![],
            lora_orders: orders,
            batch_runs: None,
        };
        let mut programs = BTreeMap::new();
        programs.insert(
            "grad_step".to_string(),
            mk(train.map(|(f, b)| LoraOrders { forward: f, backward: b })),
        );
        programs.insert(
            "eval_loss".to_string(),
            mk(eval_fwd.map(|f| LoraOrders { forward: f, backward: LoraOrder::Factored })),
        );
        Manifest {
            key: ac.key(),
            dir: std::path::PathBuf::new(),
            config: ac.clone(),
            adam: crate::config::AdamConfig::default(),
            trainable: vec![],
            frozen: vec![],
            programs,
            content_hash: None,
        }
    }

    #[test]
    fn manifest_factored_forward_matches_legacy() {
        // Legacy folds adapters into n_active at exactly the factored
        // per-token cost, so the exact model under factored orders must
        // reproduce legacy forward_flops to the FLOP.
        let ac = ac(TrainMode::Lora);
        let legacy = FlopsModel::for_artifact(&ac);
        let man = manifest_with_orders(
            &ac,
            Some((LoraOrder::Factored, LoraOrder::Factored)),
            Some(LoraOrder::Factored),
        );
        let exact = FlopsModel::for_manifest(&man);
        let tokens = ac.model.eval_batch * ac.model.seq_len;
        assert_eq!(exact.forward_flops(tokens), legacy.forward_flops(tokens));
        // train differs: exact charges the true factored backward
        // (2Mr·5d per matrix) instead of the 1:2 approximation (2Mr·4d),
        // so exact > legacy for the adapter share.
        assert!(exact.train_flops(tokens) > 0);
    }

    #[test]
    fn manifest_merged_orders_reduce_full_rank_train_cost() {
        // r = d_model (the §6.1 full-rank point): merged must beat the
        // factored accounting for both passes at ff-tiny's micro batch.
        let mut ac = ac(TrainMode::Lora);
        ac.lora_rank = ac.model.d_model;
        let merged = FlopsModel::for_manifest(&manifest_with_orders(
            &ac,
            Some((LoraOrder::Merged, LoraOrder::Merged)),
            Some(LoraOrder::Merged),
        ));
        let factored = FlopsModel::for_manifest(&manifest_with_orders(
            &ac,
            Some((LoraOrder::Factored, LoraOrder::Factored)),
            Some(LoraOrder::Factored),
        ));
        let tokens = ac.model.micro_batch * ac.model.seq_len;
        assert!(merged.train_flops(tokens) < factored.train_flops(tokens));
        assert!(merged.forward_flops(tokens) < factored.forward_flops(tokens));
    }

    #[test]
    fn manifest_without_orders_falls_back_to_legacy() {
        let ac = ac(TrainMode::Lora);
        let legacy = FlopsModel::for_artifact(&ac);
        let man = manifest_with_orders(&ac, None, None);
        let fm = FlopsModel::for_manifest(&man);
        assert_eq!(fm.forward_flops(1000), legacy.forward_flops(1000));
        assert_eq!(fm.train_flops(1000), legacy.train_flops(1000));
    }

    #[test]
    fn order_formulas_cross_over_with_rank() {
        // ff-tiny micro step shape: M = 8·64 = 512, K = N = 64.
        let (m, d) = (512, 64);
        // low rank: factored wins both passes
        assert!(
            lora_forward_flops(LoraOrder::Factored, m, d, d, 8)
                < lora_forward_flops(LoraOrder::Merged, m, d, d, 8)
        );
        assert!(
            lora_backward_flops(LoraOrder::Factored, m, d, d, 8)
                < lora_backward_flops(LoraOrder::Merged, m, d, d, 8)
        );
        // full rank: merged wins both passes
        assert!(
            lora_forward_flops(LoraOrder::Merged, m, d, d, d)
                < lora_forward_flops(LoraOrder::Factored, m, d, d, d)
        );
        assert!(
            lora_backward_flops(LoraOrder::Merged, m, d, d, d)
                < lora_backward_flops(LoraOrder::Factored, m, d, d, d)
        );
    }

    #[test]
    fn counter_partitions() {
        let fm = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        let mut c = FlopsCounter::default();
        c.sgd_step(&fm, 10);
        c.ff_probe(&fm, 10);
        c.test_eval(&fm, 1000);
        assert_eq!(
            c.total(),
            c.train_fwd_bwd + c.adam_updates + c.ff_inference + c.ff_param_updates
        );
        assert!(c.eval_inference > 0);
        // test eval excluded from chargeable total
        assert!(c.total() < c.total() + c.eval_inference);
    }
}
