//! Analytic FLOPs accounting — the paper's §4 protocol:
//!
//! > we record the total training time and number of FLOPs from all
//! > computation, including Adam SGD updates, inference on the small
//! > validation set during Fast Forward, and setting model parameters.
//!
//! Convention: forward = 2·N_matmul·tokens (Kaplan et al. 2020); backward
//! = 2× forward (Kaplan/Hoffmann 1:2 fwd:bwd); attention-score FLOPs are
//! included via the 2·T·d per-token term. Adam ≈ 10 flops/param; a FF
//! simulated step costs one val-set forward + |trainable| axpy flops
//! ("setting model parameters").

use crate::config::{ArtifactConfig, TrainMode};
use crate::model::spec;

/// Per-model static FLOPs coefficients.
#[derive(Debug, Clone, Copy)]
pub struct FlopsModel {
    /// Matmul params active in a forward pass (base + adapters).
    pub n_active: usize,
    /// Trainable parameter count (host update / Adam costs).
    pub n_trainable: usize,
    /// Attention quadratic term per token: 2 · T · d_model · n_layers.
    pub attn_per_token: usize,
}

impl FlopsModel {
    pub fn for_artifact(ac: &ArtifactConfig) -> FlopsModel {
        let m = &ac.model;
        // Matmul (weight) params touched in forward: everything except LN.
        let per_layer = 4 * m.d_model * m.d_model + 2 * m.d_model * m.d_ff();
        let base_matmul =
            m.vocab_size * m.d_model * 2 + m.seq_len * m.d_model + m.n_layers * per_layer;
        let adapters = match ac.train_mode {
            TrainMode::Lora | TrainMode::Dora => spec::n_trainable(ac),
            _ => 0,
        };
        FlopsModel {
            n_active: base_matmul + adapters,
            n_trainable: spec::n_trainable(ac),
            attn_per_token: 2 * m.seq_len * m.d_model * m.n_layers,
        }
    }

    pub fn forward_flops(&self, tokens: usize) -> u64 {
        (2 * self.n_active + self.attn_per_token) as u64 * tokens as u64
    }

    /// Forward + backward at the paper's 1:2 ratio.
    pub fn train_flops(&self, tokens: usize) -> u64 {
        3 * self.forward_flops(tokens)
    }

    pub fn adam_flops(&self) -> u64 {
        10 * self.n_trainable as u64
    }

    /// One FF simulated step: apply W += Δ (2 flops/param: mul + add).
    pub fn ff_apply_flops(&self) -> u64 {
        2 * self.n_trainable as u64
    }
}

/// Mutable run counter, accumulated by the trainer.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlopsCounter {
    pub train_fwd_bwd: u64,
    pub adam_updates: u64,
    pub ff_inference: u64,
    pub ff_param_updates: u64,
    pub eval_inference: u64,
}

impl FlopsCounter {
    /// Total chargeable FLOPs under the paper's protocol. Test-set
    /// evaluation (`eval_inference`) is the *measurement*, not the method,
    /// so it is tracked separately and excluded — same as the paper, which
    /// charges only val-set inference performed *during* Fast Forward.
    pub fn total(&self) -> u64 {
        self.train_fwd_bwd + self.adam_updates + self.ff_inference + self.ff_param_updates
    }

    pub fn sgd_step(&mut self, fm: &FlopsModel, tokens: usize) {
        self.train_fwd_bwd += fm.train_flops(tokens);
        self.adam_updates += fm.adam_flops();
    }

    pub fn ff_probe(&mut self, fm: &FlopsModel, val_tokens: usize) {
        self.ff_inference += fm.forward_flops(val_tokens);
        self.ff_param_updates += fm.ff_apply_flops();
    }

    pub fn test_eval(&mut self, fm: &FlopsModel, tokens: usize) {
        self.eval_inference += fm.forward_flops(tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn ac(mode: TrainMode) -> ArtifactConfig {
        ArtifactConfig {
            model: presets::model("ff-tiny").unwrap(),
            train_mode: mode,
            lora_rank: 8,
            lora_alpha: 16.0,
            use_pallas: false,
        }
    }

    #[test]
    fn backward_is_twice_forward() {
        let fm = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        assert_eq!(fm.train_flops(100), 3 * fm.forward_flops(100));
    }

    #[test]
    fn lora_adds_adapter_flops_but_few() {
        let base = FlopsModel::for_artifact(&ac(TrainMode::FullAttn));
        let lora = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        assert!(lora.n_active > base.n_active);
        // adapters are < 10% of the forward cost at rank 8
        assert!((lora.n_active - base.n_active) as f64 / (base.n_active as f64) < 0.10);
    }

    #[test]
    fn ff_probe_is_much_cheaper_than_sgd_step() {
        let fm = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        let mut sgd = FlopsCounter::default();
        sgd.sgd_step(&fm, 32 * 64); // global batch of 32 seqs
        let mut ff = FlopsCounter::default();
        ff.ff_probe(&fm, 32 * 64); // val set of 32 seqs: forward only
        assert!(ff.total() * 2 < sgd.total(), "{} vs {}", ff.total(), sgd.total());
    }

    #[test]
    fn counter_partitions() {
        let fm = FlopsModel::for_artifact(&ac(TrainMode::Lora));
        let mut c = FlopsCounter::default();
        c.sgd_step(&fm, 10);
        c.ff_probe(&fm, 10);
        c.test_eval(&fm, 1000);
        assert_eq!(
            c.total(),
            c.train_fwd_bwd + c.adam_updates + c.ff_inference + c.ff_param_updates
        );
        assert!(c.eval_inference > 0);
        // test eval excluded from chargeable total
        assert!(c.total() < c.total() + c.eval_inference);
    }
}
