//! End-to-end validation driver (DESIGN.md requirement): train a real
//! transformer for a few hundred optimizer steps through the full stack —
//! synthetic corpus → rust data pipeline → AOT HLO train programs on the
//! PJRT CPU client → FF controller — logging the loss curve, and recording
//! the run in EXPERIMENTS.md.
//!
//! Defaults to `ff-medium` (~13M params; minutes on one CPU core).
//! `--model ff-xl` runs the ~98M-parameter configuration that matches the
//! "~100M transformer" requirement (slow on one core — expect hours).
//!
//! Run: `cargo run --release --example e2e_train -- [--model ff-xl]
//!       [--steps N] [--no-ff] [--task chat]`

use std::path::PathBuf;

use fastforward::config::{presets, FfConfig};
use fastforward::ff::controller::FfDecision;
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;
use fastforward::util::args::Args;

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let mut args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let model = args.opt_or("model", "ff-medium");
    let task = args.opt_or("task", "chat");
    let steps = args.opt_usize("steps", 300).map_err(|e| anyhow::anyhow!(e))?;
    let no_ff = args.flag("no-ff");
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let artifacts = PathBuf::from("artifacts");
    let rt = Runtime::cpu()?;
    let base = ensure_pretrained(&rt, &artifacts, &model, None)?;

    let mut cfg = presets::train_config(&format!("{model}_lora_r8"), &task, 1)?;
    cfg.max_steps = steps;
    cfg.test_examples = 256;
    cfg.ff = if no_ff {
        FfConfig { enabled: false, ..FfConfig::default() }
    } else {
        FfConfig::default()
    };

    let mc = presets::model(&model)?;
    println!(
        "e2e: {model} ({:.1}M params), task {task}, {steps} optimizer steps, FF={}",
        mc.n_params() as f64 / 1e6,
        !no_ff
    );

    let mut t = Trainer::new(&rt, &artifacts, cfg, Some(&base))?;
    let t0 = std::time::Instant::now();
    while t.adam_steps() < steps {
        match t.ffc.next() {
            FfDecision::Sgd => {
                t.sgd_step()?;
            }
            FfDecision::FastForward => {
                t.ff_stage()?;
            }
        }
        let n = t.adam_steps();
        if n % 20 == 0 && t.log.records.last().map(|r| r.kind)
            == Some(fastforward::metrics::StepKind::Sgd)
        {
            let r = t.log.records.last().unwrap();
            println!(
                "step {n:>4} (+{} sim): loss {:.4} | {:.2e} FLOPs | {:.1}s | {:.1} steps/min",
                t.log.n_ff(),
                r.loss,
                r.flops as f64,
                r.seconds,
                n as f64 / (t0.elapsed().as_secs_f64() / 60.0)
            );
        }
    }
    let test = t.eval_test()?;
    println!("\nloss curve (every 10th step):");
    for r in t.log.records.iter().step_by(10) {
        println!(
            "  step {:>4} {} loss {:.4}",
            r.step,
            match r.kind {
                fastforward::metrics::StepKind::Sgd => "sgd",
                fastforward::metrics::StepKind::FastForward => "ff ",
            },
            r.loss
        );
    }
    println!(
        "\nfinal: test loss {test:.4} | {} adam + {} simulated steps | {:.3e} FLOPs | {:.1}s wall",
        t.adam_steps(),
        t.log.n_ff(),
        t.flops.total() as f64,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
