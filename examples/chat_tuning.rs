//! Chat tuning (paper §4, ultrachat substitute) with the *adaptive*
//! T_interval scheduler — the paper's §7 future-work extension: shrink the
//! SGD interval while FF stages stay productive, grow it when they fizzle.
//!
//! Compares fixed T_interval=6 (the paper's setting) against the adaptive
//! schedule on the multi-turn dialogue corpus.
//!
//! Run: `cargo run --release --example chat_tuning`

use std::path::PathBuf;

use fastforward::config::{presets, FfConfig};
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let artifacts = PathBuf::from("artifacts");
    let rt = Runtime::cpu()?;
    let base = ensure_pretrained(&rt, &artifacts, "ff-tiny", None)?;

    let mut results = Vec::new();
    for (label, adaptive) in [("fixed T_interval=6", false), ("adaptive interval", true)] {
        let mut cfg = presets::train_config("ff-tiny_lora_r8", "chat", 2)?;
        cfg.train_examples = 2048;
        cfg.test_examples = 256;
        cfg.ff = FfConfig { adaptive_interval: adaptive, ..FfConfig::default() };
        let steps = cfg.max_steps;
        let mut t = Trainer::new(&rt, &artifacts, cfg, Some(&base))?;
        let sum = t.run(&StopRule::MaxSteps(steps))?;
        println!(
            "{label:<20} loss {:.4} | {} adam + {} sim steps | {:.2e} FLOPs | final interval {}",
            sum.final_test_loss,
            sum.adam_steps,
            sum.sim_steps,
            sum.flops.total() as f64,
            t.ffc.interval()
        );
        let taus: Vec<usize> = t.ffc.stages.iter().map(|s| s.tau_star).collect();
        println!("  τ* per stage: {taus:?}");
        results.push((label, sum.final_test_loss, sum.flops.total()));
    }

    let (_, l_fixed, f_fixed) = results[0];
    let (_, l_adapt, f_adapt) = results[1];
    println!(
        "\nadaptive vs fixed: Δloss {:+.4}, FLOPs ratio {:.2}×",
        l_adapt - l_fixed,
        f_adapt as f64 / f_fixed as f64
    );
    Ok(())
}
