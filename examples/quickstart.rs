//! Quickstart: the smallest complete Fast Forward run.
//!
//! Pretrains (or loads the cached) tiny base model, finetunes it on the
//! medical task twice — plain Adam vs Fast Forward — and prints the FLOPs
//! and wall-clock savings at matched test loss, i.e. the paper's headline
//! measurement on one grid cell.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use std::path::PathBuf;

use fastforward::config::{presets, FfConfig};
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let artifacts = PathBuf::from("artifacts");
    let rt = Runtime::cpu()?;

    // 1. A pretrained starting point (cached under artifacts/checkpoints).
    let base = ensure_pretrained(&rt, &artifacts, "ff-tiny", None)?;

    // 2. Baseline: 2 epochs of plain Adam on the medical task.
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "medical", 2)?;
    cfg.test_examples = 256;
    cfg.ff = FfConfig { enabled: false, ..FfConfig::default() };
    let steps = cfg.max_steps;
    let mut baseline = Trainer::new(&rt, &artifacts, cfg.clone(), Some(&base))?;
    let b = baseline.run(&StopRule::MaxSteps(steps))?;
    println!(
        "baseline: loss {:.4} | {} steps | {:.2e} FLOPs | {:.1}s",
        b.final_test_loss, b.adam_steps, b.flops.total() as f64, b.train_seconds
    );

    // 3. Fast Forward: same data, run until the baseline loss is matched.
    cfg.ff = FfConfig::default();
    let mut ff = Trainer::new(&rt, &artifacts, cfg, Some(&base))?;
    let f = ff.run(&StopRule::TargetLoss {
        target: b.final_test_loss,
        eps: 3e-3,
        eval_every: 4,
        max_steps: steps * 3,
    })?;
    println!(
        "fast-fwd: loss {:.4} | {} adam + {} simulated steps | {:.2e} FLOPs | {:.1}s",
        f.final_test_loss, f.adam_steps, f.sim_steps, f.flops.total() as f64, f.train_seconds
    );

    println!(
        "\nFLOPs saved: {:.1}%   train time saved: {:.1}%   (paper Fig 2/3: 41–87%)",
        100.0 * (1.0 - f.flops.total() as f64 / b.flops.total() as f64),
        100.0 * (1.0 - f.train_seconds / b.train_seconds),
    );
    for s in &ff.ffc.stages {
        println!(
            "  ff stage {:>2} @step {:>3}: τ*={:<3} val {:.4}→{:.4}",
            s.stage, s.at_step, s.tau_star, s.baseline_loss, s.final_loss
        );
    }
    Ok(())
}
