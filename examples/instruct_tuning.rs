//! Instruction tuning with response-only loss (paper §4, Evol substitute).
//!
//! Demonstrates the loss-mask path: the instruct corpus produces
//! prompt→response examples where only response positions carry loss, and
//! Fast Forward runs on top unchanged. Prints per-epoch test loss and the
//! FF stage log.
//!
//! Run: `cargo run --release --example instruct_tuning`

use std::path::PathBuf;

use fastforward::config::presets;
use fastforward::data::corpus::make_dataset;
use fastforward::data::vocab;
use fastforward::ff::controller::FfDecision;
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let artifacts = PathBuf::from("artifacts");
    let rt = Runtime::cpu()?;
    let base = ensure_pretrained(&rt, &artifacts, "ff-small", None)?;

    let mut cfg = presets::train_config("ff-small_lora_r8", "instruct", 2)?;
    cfg.train_examples = 1536;
    cfg.test_examples = 256;
    let steps = cfg.max_steps;

    // Peek at the data to show the masking structure.
    let ds = make_dataset("instruct", 1024, 64, 4, 0, 0, cfg.seed)?;
    let ex = &ds.train[0];
    let sep = ex.seq.iter().position(|&t| t == vocab::SEP).unwrap();
    println!(
        "instruct example: {} prompt tokens (no loss) | SEP | {} loss-bearing targets",
        sep - 1,
        ex.mask.iter().filter(|&&m| m > 0.0).count()
    );

    let mut t = Trainer::new(&rt, &artifacts, cfg, Some(&base))?;
    let steps_per_epoch = (steps / 2).max(1);
    let mut next_epoch_mark = steps_per_epoch;
    while t.adam_steps() < steps {
        match t.ffc.next() {
            FfDecision::Sgd => {
                t.sgd_step()?;
            }
            FfDecision::FastForward => {
                t.ff_stage()?;
            }
        }
        if t.adam_steps() >= next_epoch_mark {
            let epoch = next_epoch_mark / steps_per_epoch;
            let test = t.eval_test()?;
            println!(
                "epoch {epoch}: test loss {test:.4} ({} simulated steps so far)",
                t.log.n_ff()
            );
            next_epoch_mark += steps_per_epoch;
        }
    }
    println!(
        "\nfinal: {} adam + {} simulated steps | {:.2e} FLOPs | {} FF stages",
        t.adam_steps(),
        t.log.n_ff(),
        t.flops.total() as f64,
        t.ffc.n_stages()
    );
    Ok(())
}
