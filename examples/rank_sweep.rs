//! Rank sweep (paper Fig 7 as a library-API walkthrough): train LoRA
//! adapters at ranks 1–64 on the medical task and print how Fast Forward
//! behaviour (τ* and FLOPs) scales with rank — including rank 64, which
//! equals d_model for ff-tiny, i.e. the paper's "LoRA full rank" setting.
//!
//! Run: `cargo run --release --example rank_sweep -- [--steps N]`

use std::path::PathBuf;

use fastforward::config::presets;
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};
use fastforward::util::args::Args;

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let mut args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let steps = args.opt_usize("steps", 40).map_err(|e| anyhow::anyhow!(e))?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let artifacts = PathBuf::from("artifacts");
    let rt = Runtime::cpu()?;
    let base = ensure_pretrained(&rt, &artifacts, "ff-tiny", None)?;

    println!("{:>5} {:>10} {:>8} {:>9} {:>12}", "rank", "trainable", "sim", "loss", "FLOPs");
    for rank in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut cfg = presets::train_config(&format!("ff-tiny_lora_r{rank}"), "medical", 1)?;
        cfg.max_steps = steps;
        cfg.train_examples = 1024;
        cfg.test_examples = 128;
        let mut t = Trainer::new(&rt, &artifacts, cfg, Some(&base))?;
        let sum = t.run(&StopRule::MaxSteps(steps))?;
        println!(
            "{:>5} {:>10} {:>8} {:>9.4} {:>12.3e}{}",
            rank,
            fastforward::model::spec::n_trainable(&t.art.manifest.config),
            sum.sim_steps,
            sum.final_test_loss,
            sum.flops.total() as f64,
            if rank == 64 { "   <- rank == d_model (\"LoRA full rank\", §6.1)" } else { "" }
        );
    }
    Ok(())
}
