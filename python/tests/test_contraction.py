"""Contraction-order chooser: analytic-argmin property + numeric parity.

No hypothesis dependency — the property test enumerates an explicit
(d, r, batch, seq) grid, brute-forces the FLOP argmin from the cost
formulas, and asserts the chooser agrees. A second group checks the two
orders compute the same function (forward and custom-VJP backward), so
the chooser is free to pick either without changing results beyond
float re-association.
"""

from __future__ import annotations

import itertools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, contraction, model
from tests.conftest import init_params, make_batch, tiny_ac

# The grid deliberately straddles the crossover: r from tiny to full-rank
# (r = d), M from a single short row to a large token block.
GRID_D = (32, 64, 256)
GRID_R_FRAC = (1, 8, 32, 64)       # rank candidates, capped at d
GRID_M = (8, 512, 8192)            # batch*seq products


def _grid():
    for d, r, m in itertools.product(GRID_D, GRID_R_FRAC, GRID_M):
        if r <= d:
            yield m, d, d, r


def test_chooser_picks_analytic_forward_minimum():
    for m, k, n, r in _grid():
        best = min(contraction.ORDERS,
                   key=lambda o: contraction.forward_flops(o, m, k, n, r))
        chosen = contraction.choose_forward(m, k, n, r)
        assert (contraction.forward_flops(chosen, m, k, n, r)
                == contraction.forward_flops(best, m, k, n, r)), (m, k, n, r)


def test_chooser_picks_analytic_backward_minimum():
    for m, k, n, r in _grid():
        best = min(contraction.ORDERS,
                   key=lambda o: contraction.backward_flops(o, m, k, n, r))
        chosen = contraction.choose_backward(m, k, n, r)
        assert (contraction.backward_flops(chosen, m, k, n, r)
                == contraction.backward_flops(best, m, k, n, r)), (m, k, n, r)


def test_tie_breaks_to_factored():
    """Equal-cost shapes must keep the legacy order so re-emitted artifacts
    stay stable."""
    for m, k, n, r in _grid():
        if (contraction.forward_flops("factored", m, k, n, r)
                == contraction.forward_flops("merged", m, k, n, r)):
            assert contraction.choose_forward(m, k, n, r) == "factored"


def test_both_orders_exercised_by_default_artifact_set():
    """The rank sweep (r=1..64 on ff-tiny) must cross the boundary in both
    directions — otherwise the merged path ships untested by any artifact."""
    ac0 = tiny_ac()
    m = ac0.model.micro_batch * ac0.model.seq_len
    d = ac0.model.d_model
    fwd = {contraction.choose_forward(m, d, d, r)
           for r in (1, 2, 4, 8, 16, 32, 64)}
    bwd = {contraction.choose_backward(m, d, d, r)
           for r in (1, 2, 4, 8, 16, 32, 64)}
    assert fwd == set(contraction.ORDERS)
    assert bwd == set(contraction.ORDERS)


def test_merged_beats_factored_at_full_rank():
    """At r = d (the §6.1 full-rank LoRA point) merged must win both ways
    whenever M > d — the motivating case from arXiv:2312.03415."""
    for d in GRID_D:
        m = 8 * d
        assert contraction.choose_forward(m, d, d, d) == contraction.MERGED
        assert contraction.choose_backward(m, d, d, d) == contraction.MERGED


def _loss_grad(ac, tr, fr, batch):
    tok, tgt, msk = batch
    return jax.value_and_grad(
        lambda t: model.loss_fn(ac, t, fr, tok, tgt, msk))(tr)


@pytest.mark.parametrize("orders", [
    ("factored", "factored"), ("merged", "merged"),
    ("factored", "merged"), ("merged", "factored"),
])
def test_orders_compute_the_same_function(orders):
    """All four (fwd, bwd) order combinations agree numerically on one
    projection — forward values and dx/dA/dB cotangents."""
    rng = np.random.default_rng(11)
    m_, k, n, r = 24, 16, 16, 6
    x = jnp.asarray(rng.normal(0, 1, (2, 12, k)), jnp.float32)
    w0 = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    a = jnp.asarray(rng.normal(0, 1, (k, r)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (r, n)), jnp.float32)
    scale = 1.25

    def f(ordr, x, a, b):
        return (model._lora_proj(x, w0, a, b, scale, ordr, False) ** 2).sum()

    ref = ("factored", "factored")
    y = f(orders, x, a, b)
    y_ref = f(ref, x, a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    gx, ga, gb = jax.grad(f, argnums=(1, 2, 3))(orders, x, a, b)
    rx, ra, rb = jax.grad(f, argnums=(1, 2, 3))(ref, x, a, b)
    for got, want in ((gx, rx), (ga, ra), (gb, rb)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-4)


def test_full_model_grads_match_across_rank_orders():
    """End-to-end: an r=64 (merged-order) artifact config and a hand-forced
    factored trace give the same loss/grads within float tolerance."""
    rng = np.random.default_rng(7)
    ac = tiny_ac(rank=64)
    m = ac.model.micro_batch * ac.model.seq_len
    d = ac.model.d_model
    # sanity: this shape actually selects merged for both passes
    assert contraction.choose_forward(m, d, d, 64) == contraction.MERGED
    assert contraction.choose_backward(m, d, d, 64) == contraction.MERGED
    tr = init_params(configs.trainable_spec(ac), rng)
    tr = [t + 0.01 for t in tr]
    fr = init_params(configs.frozen_spec(ac), np.random.default_rng(8))
    batch = make_batch(ac, rng)
    loss_m, grads_m = _loss_grad(ac, tr, fr, batch)

    forced = {}
    orig = model._proj_orders
    try:
        model._proj_orders = lambda *a: ("factored", "factored")
        loss_f, grads_f = _loss_grad(ac, tr, fr, batch)
    finally:
        model._proj_orders = orig
    np.testing.assert_allclose(np.asarray(loss_m), np.asarray(loss_f),
                               rtol=1e-5, atol=1e-6)
    for gm, gf in zip(grads_m, grads_f):
        np.testing.assert_allclose(np.asarray(gm), np.asarray(gf),
                                   rtol=1e-3, atol=1e-5)


def test_program_orders_match_proj_orders():
    """The manifest-recorded orders must equal what the trace chose."""
    for r in (4, 64):
        ac = tiny_ac(rank=r)
        d = ac.model.d_model
        w0 = jnp.zeros((d, d), jnp.float32)
        for program, batch in (("train_step", ac.model.micro_batch),
                               ("grad_step", ac.model.micro_batch),
                               ("eval_loss", ac.model.eval_batch)):
            rec = model.program_orders(ac, program)
            x = jnp.zeros((batch, ac.model.seq_len, d), jnp.float32)
            fwd, bwd = model._proj_orders(ac, x, w0)
            assert rec["forward"] == fwd, (r, program)
            if program != "eval_loss":
                assert rec["backward"] == bwd, (r, program)
    # non-LoRA modes and the elementwise programs record nothing
    assert model.program_orders(tiny_ac("full_attn"), "train_step") is None
    assert model.program_orders(tiny_ac(), "adam_apply") is None
    # pallas pins the fused forward to factored accounting
    rec = model.program_orders(tiny_ac(rank=64, pallas=True), "grad_step")
    assert rec["forward"] == contraction.FACTORED
    assert rec["backward"] == contraction.MERGED
