"""LoFT-style optimizer-state realignment: the ``loft_realign`` program
must decay the Adam first moment by ``decay`` and the second moment by
``decay²`` (so the per-coordinate step scale m/√v shrinks by exactly
``decay`` — the realignment the rust ``loft`` backend dispatches after
each FF stage), and must reduce to the plain Adam baseline at decay=1."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile import configs, model
from compile.configs import ArtifactConfig, MODELS


def tiny_ac() -> ArtifactConfig:
    return ArtifactConfig(MODELS["ff-tiny"], "lora", lora_rank=2)


def random_state(ac, seed):
    rng = np.random.default_rng(seed)
    shapes = [p.shape for p in configs.trainable_spec(ac)]
    m = [rng.normal(0, 0.1, s).astype(np.float32) for s in shapes]
    v = [np.abs(rng.normal(0, 0.01, s)).astype(np.float32) for s in shapes]
    return m, v


def test_loft_realign_scales_m_by_decay_and_v_by_decay_squared():
    ac = tiny_ac()
    fn, _ = model.PROGRAM_FACTORIES["loft_realign"](ac)
    m, v = random_state(ac, 0)
    decay = np.float32(0.5)
    out = fn([jnp.asarray(x) for x in m], [jnp.asarray(x) for x in v], decay)
    n = len(m)
    assert len(out) == 2 * n
    for i in range(n):
        np.testing.assert_allclose(np.asarray(out[i]), m[i] * 0.5,
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(out[n + i]), v[i] * 0.25,
                                   rtol=1e-6, atol=1e-8)


def test_loft_realign_at_decay_one_is_the_adam_baseline():
    """decay=1 must be a no-op: the realigned state drives ``adam_update``
    to bit-for-bit the same weights as never realigning (the solo-vs-
    baseline equivalence the rust selftest asserts end to end)."""
    ac = tiny_ac()
    fn, _ = model.PROGRAM_FACTORIES["loft_realign"](ac)
    m, v = random_state(ac, 1)
    rng = np.random.default_rng(2)
    shapes = [p.shape for p in configs.trainable_spec(ac)]
    w = [rng.normal(0, 1, s).astype(np.float32) for s in shapes]
    g = [rng.normal(0, 1, s).astype(np.float32) for s in shapes]
    out = fn([jnp.asarray(x) for x in m], [jnp.asarray(x) for x in v],
             np.float32(1.0))
    n = len(m)
    m2, v2 = list(out[:n]), list(out[n:])
    step = jnp.asarray(3.0, jnp.float32)
    lr = jnp.asarray(1e-3, jnp.float32)
    jw = [jnp.asarray(x) for x in w]
    jg = [jnp.asarray(x) for x in g]
    base_w, base_m, base_v = model.adam_update(
        jw, [jnp.asarray(x) for x in m], [jnp.asarray(x) for x in v],
        step, jg, lr)
    loft_w, loft_m, loft_v = model.adam_update(jw, m2, v2, step, jg, lr)
    for a, b in zip(base_w + base_m + base_v, loft_w + loft_m + loft_v):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loft_realign_preserves_the_per_coordinate_step_direction():
    """m→decay·m, v→decay²·v keeps m/√v invariant: the realignment damps
    the *magnitude* of the accumulated moments (so fresh post-FF gradients
    dominate sooner) without jolting the per-coordinate step scale — the
    property that distinguishes LoFT realignment from a plain state reset."""
    ac = tiny_ac()
    fn, _ = model.PROGRAM_FACTORIES["loft_realign"](ac)
    m, v = random_state(ac, 3)
    v = [np.maximum(x, 1e-4) for x in v]
    decay = 0.25
    out = fn([jnp.asarray(x) for x in m], [jnp.asarray(x) for x in v],
             np.float32(decay))
    n = len(m)
    for i in range(n):
        before = m[i] / np.sqrt(v[i])
        after = np.asarray(out[i]) / np.sqrt(np.asarray(out[n + i]))
        np.testing.assert_allclose(after, before, rtol=1e-4, atol=1e-6)
        # while the raw moment magnitudes really do shrink
        assert np.abs(np.asarray(out[i])).max() <= 0.3 * np.abs(m[i]).max()


def test_loft_realign_program_io_and_donation():
    """Manifest contract: inputs are m then v then the decay scalar, the
    outputs alias the donated m/v slots (in-place realign on device)."""
    ac = tiny_ac()
    ins, outs = model.program_io(ac, "loft_realign")
    nt = len(configs.trainable_spec(ac))
    assert len(ins) == 2 * nt + 1 and len(outs) == 2 * nt
    assert ins[-1]["name"] == "decay" and ins[-1]["shape"] == []
    assert all(i["name"].startswith("m:") for i in ins[:nt])
    assert all(i["name"].startswith("v:") for i in ins[nt:2 * nt])
    assert model.donated_input_slots(ac, "loft_realign") == list(range(2 * nt))
    assert model.program_orders(ac, "loft_realign") is None
