"""AOT artifact smoke tests: lowering emits parseable HLO + a coherent manifest."""

from __future__ import annotations

import json
import os

import jax
import pytest

from compile import aot, configs, model
from compile.configs import ArtifactConfig, MODELS


@pytest.fixture(scope="module")
def smoke_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    ac = ArtifactConfig(MODELS["ff-tiny"], "lora", lora_rank=2)
    aot.emit_artifact(ac, str(out))
    return out, ac


def test_hlo_text_has_entry(smoke_dir):
    out, ac = smoke_dir
    for program in configs.PROGRAMS:
        text = (out / ac.key / f"{program}.hlo.txt").read_text()
        assert "ENTRY" in text and "HloModule" in text, program


def test_manifest_matches_spec(smoke_dir):
    out, ac = smoke_dir
    man = json.loads((out / ac.key / "manifest.json").read_text())
    assert man["key"] == ac.key
    assert man["config"]["lora_rank"] == 2
    assert [p["name"] for p in man["trainable"]] == [
        p.name for p in configs.trainable_spec(ac)]
    assert [p["name"] for p in man["frozen"]] == [
        p.name for p in configs.frozen_spec(ac)]
    for program in configs.PROGRAMS:
        ins, outs = model.program_io(ac, program)
        assert man["programs"][program]["inputs"] == ins
        assert man["programs"][program]["outputs"] == outs


def test_hlo_parameter_count_matches_manifest(smoke_dir):
    """The lowered module must declare exactly the inputs the manifest lists.

    The ENTRY computation is the last one in jax-emitted HLO text, so every
    ``parameter(N)`` declaration after the ENTRY marker is a program input.
    """
    out, ac = smoke_dir
    for program in configs.PROGRAMS:
        text = (out / ac.key / f"{program}.hlo.txt").read_text()
        ins, _ = model.program_io(ac, program)
        entry = text[text.index("ENTRY"):]
        n_args = entry.count(" parameter(")
        assert n_args == len(ins), (program, n_args, len(ins))


def test_donated_programs_carry_input_output_alias(smoke_dir):
    """Donation must survive the StableHLO → HLO-text lowering: the rust
    runtime relies on the alias map both for in-place buffer reuse and for
    the donated-inputs-are-invalidated contract."""
    out, ac = smoke_dir
    for program in configs.PROGRAMS:
        text = (out / ac.key / f"{program}.hlo.txt").read_text()
        aliased = "input_output_alias" in text
        if program in model.PROGRAM_DONATE:
            assert aliased, f"{program}: donation lost in lowering"
        else:
            # non-donated programs keep their inputs valid across calls
            # (the coordinator reuses parameter buffers between steps)
            assert not aliased, f"{program}: unexpected aliasing"


def test_manifest_donated_slots_expand_argnums(smoke_dir):
    """donate_argnums are function-argument positions; the manifest records
    the flattened leaf slots the rust runtime validates against."""
    out, ac = smoke_dir
    man = json.loads((out / ac.key / "manifest.json").read_text())
    nt = len(configs.trainable_spec(ac))
    # adam_apply inputs: [t..nt, m..nt, v..nt, step, g..nt, lr]
    want_adam = list(range(3 * nt)) + list(range(3 * nt + 1, 4 * nt + 1))
    assert man["programs"]["adam_apply"]["donated_inputs"] == want_adam
    assert man["programs"]["grad_accum"]["donated_inputs"] == list(range(nt))
    assert man["programs"]["grad_finalize"]["donated_inputs"] == list(range(nt))
    assert man["programs"]["grad_step"]["donated_inputs"] == []
    assert man["programs"]["train_step"]["donated_inputs"] == []


def test_manifest_records_orders_and_batch_runs(smoke_dir):
    """New manifest fields: ``lora_orders`` on every program with a LoRA
    matmul (solo and batched), ``batch_runs`` on batched variants only."""
    out, ac = smoke_dir
    man = json.loads((out / ac.key / "manifest.json").read_text())
    progs = man["programs"]
    for name in ("train_step", "grad_step"):
        assert progs[name]["lora_orders"] == model.program_orders(ac, name)
        assert set(progs[name]["lora_orders"]) == {"forward", "backward"}
        assert "batch_runs" not in progs[name]
    assert set(progs["eval_loss"]["lora_orders"]) == {"forward"}
    for name in ("grad_accum", "grad_finalize", "adam_apply"):
        assert "lora_orders" not in progs[name]
    for runs in configs.BATCHED_RUN_COUNTS:
        for base in configs.BATCHED_BASES:
            entry = progs[f"{base}_batched{runs}"]
            assert entry["batch_runs"] == runs
            # the run axis is the leading dim of every stacked input
            t0 = next(i for i in entry["inputs"] if i["name"].startswith("t:"))
            assert t0["shape"][0] == runs
    # batched donation survives lowering; grad/eval stay alias-free
    for runs in configs.BATCHED_RUN_COUNTS:
        for base in configs.BATCHED_BASES:
            text = (out / ac.key / f"{base}_batched{runs}.hlo.txt").read_text()
            if base in ("train_step", "adam_apply"):
                assert "input_output_alias" in text, (base, runs)
            else:
                assert "input_output_alias" not in text, (base, runs)


def test_grad_accum_and_finalize_compute_the_mean(smoke_dir):
    """acc/finalize chained over micro-batch grads == the arithmetic mean
    (mirrors rust/src/optim/accum.rs and the trainer's device path)."""
    import numpy as np

    _, ac = smoke_dir
    accum_fn, _ = model.PROGRAM_FACTORIES["grad_accum"](ac)
    fin_fn, _ = model.PROGRAM_FACTORIES["grad_finalize"](ac)
    rng = np.random.default_rng(0)
    shapes = [p.shape for p in configs.trainable_spec(ac)]
    micros = [[rng.normal(size=s).astype(np.float32) for s in shapes]
              for _ in range(3)]
    acc = list(micros[0])
    for g in micros[1:]:
        acc = list(accum_fn(acc, g))
    mean = fin_fn(acc, np.float32(1.0 / 3.0))
    for i, s in enumerate(shapes):
        want = (micros[0][i] + micros[1][i] + micros[2][i]) / 3.0
        np.testing.assert_allclose(np.asarray(mean[i]), want, rtol=1e-6,
                                   atol=1e-6)


def test_manifest_content_hash_stamp(smoke_dir):
    """The stamp contract the rust store relies on: content_hash is the
    trailing top-level key, stripping its suffix recovers the canonical
    bytes, and the hash covers manifest + HLO bytes (so touching either
    changes it)."""
    out, ac = smoke_dir
    path = out / ac.key / "manifest.json"
    text = path.read_text()
    man = json.loads(text)
    recorded = man["content_hash"]
    assert len(recorded) == 64 and int(recorded, 16) >= 0
    suffix = ',\n "content_hash": "%s"\n}' % recorded
    assert text.endswith(suffix)
    body = {k: v for k, v in man.items() if k != "content_hash"}
    assert text[: -len(suffix)] + "\n}" == json.dumps(body, indent=1)
    assert aot.content_hash(man, str(out / ac.key)) == recorded
    # Sensitivity: flipping one HLO byte must change the hash.
    hlo = out / ac.key / "train_step.hlo.txt"
    original = hlo.read_text()
    try:
        hlo.write_text(original + " ")
        assert aot.content_hash(man, str(out / ac.key)) != recorded
    finally:
        hlo.write_text(original)


def test_emit_is_incremental(smoke_dir, capsys):
    out, ac = smoke_dir
    aot.emit_artifact(ac, str(out))
    captured = capsys.readouterr().out
    assert "[cached]" in captured and "[lowered]" not in captured


def test_stale_alias_hlo_is_relowered(smoke_dir, capsys):
    """A cached HLO whose alias map disagrees with what the manifest will
    claim (e.g. artifacts from a checkout with different PROGRAM_DONATE)
    must be re-lowered, not trusted — otherwise the rust runtime's
    donation guards validate against the wrong executable."""
    out, ac = smoke_dir
    p = out / ac.key / "adam_apply.hlo.txt"
    original = p.read_text()
    stripped = original.replace("may-alias", "no-alias")
    assert aot.alias_count(stripped) == 0 < aot.alias_count(original)
    p.write_text(stripped)  # mtime is now fresh: plain cache would keep it
    aot.emit_artifact(ac, str(out))
    captured = capsys.readouterr().out
    assert "[stale-alias]" in captured
    assert aot.alias_count(p.read_text()) == aot.alias_count(original)


def test_index_merge(tmp_path):
    """--only runs must not clobber unrelated index entries."""
    import subprocess, sys
    env = dict(os.environ)
    cwd = os.path.join(os.path.dirname(__file__), "..")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "no-such-artifact-key"],  # no match → exit 1
        capture_output=True, cwd=cwd, env=env)
    assert r.returncode == 1
