"""AOT artifact smoke tests: lowering emits parseable HLO + a coherent manifest."""

from __future__ import annotations

import json
import os

import jax
import pytest

from compile import aot, configs, model
from compile.configs import ArtifactConfig, MODELS


@pytest.fixture(scope="module")
def smoke_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    ac = ArtifactConfig(MODELS["ff-tiny"], "lora", lora_rank=2)
    aot.emit_artifact(ac, str(out))
    return out, ac


def test_hlo_text_has_entry(smoke_dir):
    out, ac = smoke_dir
    for program in configs.PROGRAMS:
        text = (out / ac.key / f"{program}.hlo.txt").read_text()
        assert "ENTRY" in text and "HloModule" in text, program


def test_manifest_matches_spec(smoke_dir):
    out, ac = smoke_dir
    man = json.loads((out / ac.key / "manifest.json").read_text())
    assert man["key"] == ac.key
    assert man["config"]["lora_rank"] == 2
    assert [p["name"] for p in man["trainable"]] == [
        p.name for p in configs.trainable_spec(ac)]
    assert [p["name"] for p in man["frozen"]] == [
        p.name for p in configs.frozen_spec(ac)]
    for program in configs.PROGRAMS:
        ins, outs = model.program_io(ac, program)
        assert man["programs"][program]["inputs"] == ins
        assert man["programs"][program]["outputs"] == outs


def test_hlo_parameter_count_matches_manifest(smoke_dir):
    """The lowered module must declare exactly the inputs the manifest lists.

    The ENTRY computation is the last one in jax-emitted HLO text, so every
    ``parameter(N)`` declaration after the ENTRY marker is a program input.
    """
    out, ac = smoke_dir
    for program in configs.PROGRAMS:
        text = (out / ac.key / f"{program}.hlo.txt").read_text()
        ins, _ = model.program_io(ac, program)
        entry = text[text.index("ENTRY"):]
        n_args = entry.count(" parameter(")
        assert n_args == len(ins), (program, n_args, len(ins))


def test_emit_is_incremental(smoke_dir, capsys):
    out, ac = smoke_dir
    aot.emit_artifact(ac, str(out))
    captured = capsys.readouterr().out
    assert "[cached]" in captured and "[lowered]" not in captured


def test_index_merge(tmp_path):
    """--only runs must not clobber unrelated index entries."""
    import subprocess, sys
    env = dict(os.environ)
    cwd = os.path.join(os.path.dirname(__file__), "..")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "no-such-artifact-key"],  # no match → exit 1
        capture_output=True, cwd=cwd, env=env)
    assert r.returncode == 1
