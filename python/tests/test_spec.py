"""Parameter-spec and manifest-schema invariants.

These guard the python↔rust contract: the rust coordinator re-derives the
identical spec in rust/src/model/spec.rs and refuses artifacts that drift.
"""

from __future__ import annotations

import pytest

from compile import configs, model
from compile.configs import (ArtifactConfig, MODELS, PROGRAMS, frozen_spec,
                             n_trainable, param_spec, trainable_spec)


@pytest.mark.parametrize("mode", configs.TRAIN_MODES)
@pytest.mark.parametrize("mname", ["ff-tiny", "ff-small"])
def test_spec_names_unique_and_ordered(mode, mname):
    ac = ArtifactConfig(MODELS[mname], mode)
    spec = param_spec(ac)
    names = [p.name for p in spec]
    assert len(names) == len(set(names))
    # trainables-then-frozen partition preserves relative order
    t_names = [p.name for p in trainable_spec(ac)]
    f_names = [p.name for p in frozen_spec(ac)]
    assert [n for n in names if n in set(t_names)] == t_names
    assert [n for n in names if n in set(f_names)] == f_names


def test_lora_trainable_counts():
    ac = ArtifactConfig(MODELS["ff-tiny"], "lora", lora_rank=8)
    m = ac.model
    # 4 matrices × (A: d·r + B: r·d) per layer
    expect = m.n_layers * 4 * 2 * m.d_model * 8
    assert n_trainable(ac) == expect


def test_dora_adds_magnitude_vectors():
    lo = ArtifactConfig(MODELS["ff-tiny"], "lora", lora_rank=8)
    do = ArtifactConfig(MODELS["ff-tiny"], "dora", lora_rank=8)
    m = lo.model
    assert n_trainable(do) - n_trainable(lo) == m.n_layers * 4 * m.d_model


def test_full_attn_trainables_are_attention_matrices():
    ac = ArtifactConfig(MODELS["ff-tiny"], "full_attn")
    t = trainable_spec(ac)
    assert all(".attn.w" in p.name for p in t)
    assert len(t) == ac.model.n_layers * 4


def test_full_all_has_no_frozen():
    ac = ArtifactConfig(MODELS["ff-tiny"], "full_all")
    assert frozen_spec(ac) == []
    assert n_trainable(ac) == ac.model.n_params()


def test_n_params_matches_spec_product():
    for name, mc in MODELS.items():
        ac = ArtifactConfig(mc, "full_all")
        total = 0
        for p in param_spec(ac):
            n = 1
            for s in p.shape:
                n *= s
            total += n
        assert total == mc.n_params(), name


def test_model_size_ladder():
    """Substitution ladder (DESIGN.md): sizes strictly increase, xl ≈ 100M."""
    sizes = [MODELS[n].n_params() for n in
             ("ff-tiny", "ff-small", "ff-medium", "ff-large", "ff-xl")]
    assert sizes == sorted(sizes)
    assert sizes[-1] > 80e6


@pytest.mark.parametrize("program", PROGRAMS)
def test_program_io_arity_matches_factories(program):
    ac = ArtifactConfig(MODELS["ff-tiny"], "lora")
    ins, outs = model.program_io(ac, program)
    _, args = model.PROGRAM_FACTORIES[program](ac)
    n_in = sum(len(a) if isinstance(a, (list, tuple)) else 1 for a in args)
    assert n_in == len(ins)
    nt = len(trainable_spec(ac))
    expect_out = {"train_step": 1 + 3 * nt, "grad_step": 1 + nt,
                  "grad_accum": nt, "grad_finalize": nt,
                  "adam_apply": 3 * nt, "eval_loss": 1,
                  "loft_realign": 2 * nt}[program]
    assert len(outs) == expect_out


def test_artifact_keys_stable():
    assert _key("ff-tiny", "lora", 8) == "ff-tiny_lora_r8"
    assert _key("ff-tiny", "full_attn", 8) == "ff-tiny_full_attn"
    ac = ArtifactConfig(MODELS["ff-tiny"], "lora", lora_rank=8, use_pallas=True)
    assert ac.key == "ff-tiny_lora_r8_pallas"


def _key(m, mode, r):
    return ArtifactConfig(MODELS[m], mode, lora_rank=r).key


def test_default_artifact_set_covers_experiments():
    keys = {ac.key for ac in configs.default_artifact_set()}
    # fig2 grid
    for m in ("ff-tiny", "ff-small", "ff-medium", "ff-large"):
        assert f"{m}_lora_r8" in keys
        assert f"{m}_dora_r8" in keys
        assert f"{m}_full_all" in keys  # pretraining substrate
    # fig7 rank sweep
    for r in (1, 2, 4, 16, 32, 64):
        assert f"ff-tiny_lora_r{r}" in keys
    assert "ff-tiny_full_attn" in keys           # fig8
    assert "ff-tiny_lora_r64" in keys
    assert "ff-tiny_lora_r8_pallas" in keys      # L1 composition proof
    assert "ff-xl_lora_r8" in keys               # e2e driver
