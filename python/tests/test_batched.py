"""Batched (vmapped) program variants vs solo programs — bit-identity.

The rust queue packs K same-artifact runs into one ``*_batched{K}``
dispatch and promises each tenant bit-identical losses vs running solo
(docs/step-pipeline.md). That promise is only as good as XLA compiling
the vmapped body to the same per-run arithmetic as the solo program, so
these tests compare *compiled* outputs byte-for-byte (``tobytes``), not
within tolerance. The fused-vs-chained test pins the other half of the
contract: the solo engine steps via grad_step → grad_finalize(×1.0) →
adam_apply, so the batched runner must use the chained pair too unless
the fused ``train_step`` is proven bitwise-equal to the chain.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model
from tests.conftest import init_params, make_batch, tiny_ac

RUNS = 2


def _bitwise_equal(got, want, ctx=""):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, (ctx, got.shape, want.shape)
    assert got.tobytes() == want.tobytes(), (
        f"{ctx}: max abs diff "
        f"{np.abs(got.astype(np.float64) - want.astype(np.float64)).max()}")


def _runs_state(ac, seed=0):
    """Per-run (trainables, m, v, step, lr, batch) for RUNS distinct runs
    over a shared frozen base."""
    fr = init_params(configs.frozen_spec(ac), np.random.default_rng(99))
    runs = []
    for i in range(RUNS):
        rng = np.random.default_rng(seed + 10 * i)
        tr = init_params(configs.trainable_spec(ac), rng)
        tr = [t + 0.01 * (i + 1) for t in tr]   # distinct adapters per run
        m = [jnp.zeros_like(t) for t in tr]
        v = [jnp.zeros_like(t) for t in tr]
        step = jnp.asarray(float(i), jnp.float32)
        lr = jnp.asarray(1e-3 * (i + 1), jnp.float32)
        batch = make_batch(ac, rng)
        runs.append((tr, m, v, step, lr, batch))
    return fr, runs


def _stack(runs, idx):
    """Stack component ``idx`` (a list of arrays per run) along axis 0."""
    return [jnp.stack([r[idx][j] for r in runs])
            for j in range(len(runs[0][idx]))]


def _stack_scalar(runs, idx):
    return jnp.stack([r[idx] for r in runs])


def _stack_batch(runs):
    return tuple(jnp.stack([r[5][j] for r in runs]) for j in range(3))


def test_grad_step_batched_bitwise_equals_solo():
    ac = tiny_ac()
    fr, runs = _runs_state(ac)
    solo_fn, _ = model.PROGRAM_FACTORIES["grad_step"](ac)
    solo = jax.jit(solo_fn)
    bat_fn, _ = model.BATCHED_FACTORIES["grad_step"](ac, RUNS)
    batched = jax.jit(bat_fn)

    tok, tgt, msk = _stack_batch(runs)
    out_b = batched(_stack(runs, 0), fr, tok, tgt, msk)
    for i, (tr, _, _, _, _, (tki, tgi, mki)) in enumerate(runs):
        out_s = solo(tr, fr, tki, tgi, mki)
        _bitwise_equal(out_b[0][i], out_s[0], f"run{i} loss")
        for j in range(1, len(out_s)):
            _bitwise_equal(out_b[j][i], out_s[j], f"run{i} grad{j}")


def test_adam_apply_batched_bitwise_equals_solo():
    ac = tiny_ac()
    fr, runs = _runs_state(ac)
    # use real grads so the update exercises non-trivial values
    gs_fn = jax.jit(model.PROGRAM_FACTORIES["grad_step"](ac)[0])
    grads = [gs_fn(r[0], fr, *r[5])[1:] for r in runs]

    solo = jax.jit(model.PROGRAM_FACTORIES["adam_apply"](ac)[0])
    batched = jax.jit(model.BATCHED_FACTORIES["adam_apply"](ac, RUNS)[0])
    g_stacked = [jnp.stack([g[j] for g in grads])
                 for j in range(len(grads[0]))]
    out_b = batched(_stack(runs, 0), _stack(runs, 1), _stack(runs, 2),
                    _stack_scalar(runs, 3), g_stacked, _stack_scalar(runs, 4))
    for i, (tr, m, v, step, lr, _) in enumerate(runs):
        out_s = solo(tr, m, v, step, list(grads[i]), lr)
        for j in range(len(out_s)):
            _bitwise_equal(out_b[j][i], out_s[j], f"run{i} out{j}")


def test_eval_loss_batched_bitwise_equals_solo():
    ac = tiny_ac()
    fr, runs = _runs_state(ac)
    eb = ac.model.eval_batch
    solo = jax.jit(model.PROGRAM_FACTORIES["eval_loss"](ac)[0])
    batched = jax.jit(model.BATCHED_FACTORIES["eval_loss"](ac, RUNS)[0])
    batches = [make_batch(ac, np.random.default_rng(40 + i), batch=eb)
               for i in range(RUNS)]
    tok, tgt, msk = (jnp.stack([b[j] for b in batches]) for j in range(3))
    out_b = batched(_stack(runs, 0), fr, tok, tgt, msk)
    for i, r in enumerate(runs):
        out_s = solo(r[0], fr, *batches[i])
        _bitwise_equal(out_b[0][i], out_s[0], f"run{i} eval loss")


def test_train_step_batched_bitwise_equals_solo():
    ac = tiny_ac()
    fr, runs = _runs_state(ac)
    solo = jax.jit(model.PROGRAM_FACTORIES["train_step"](ac)[0])
    batched = jax.jit(model.BATCHED_FACTORIES["train_step"](ac, RUNS)[0])
    tok, tgt, msk = _stack_batch(runs)
    out_b = batched(_stack(runs, 0), _stack(runs, 1), _stack(runs, 2),
                    _stack_scalar(runs, 3), fr, tok, tgt, msk,
                    _stack_scalar(runs, 4))
    for i, (tr, m, v, step, lr, (tki, tgi, mki)) in enumerate(runs):
        out_s = solo(tr, m, v, step, fr, tki, tgi, mki, lr)
        for j in range(len(out_s)):
            _bitwise_equal(out_b[j][i], out_s[j], f"run{i} out{j}")


def test_fused_train_step_vs_chained_grad_adam():
    """Decides the rust batched dispatch design: the solo engine never runs
    the fused train_step (it chains grad_step → grad_finalize(×1.0) →
    adam_apply), so bit-identical packing may only use the fused batched
    program if fused == chained bitwise. If this test ever starts failing
    the batched runner must stay on the chained pair (it currently does —
    see rust/src/train/batched.rs)."""
    ac = tiny_ac()
    fr, runs = _runs_state(ac)
    tr, m, v, step, lr, (tok, tgt, msk) = runs[0]

    fused = jax.jit(model.PROGRAM_FACTORIES["train_step"](ac)[0])
    out_f = fused(tr, m, v, step, fr, tok, tgt, msk, lr)

    gs = jax.jit(model.PROGRAM_FACTORIES["grad_step"](ac)[0])
    fin = jax.jit(model.PROGRAM_FACTORIES["grad_finalize"](ac)[0])
    ad = jax.jit(model.PROGRAM_FACTORIES["adam_apply"](ac)[0])
    loss_and_g = gs(tr, fr, tok, tgt, msk)
    g = fin(list(loss_and_g[1:]), jnp.asarray(1.0, jnp.float32))
    out_c = ad(tr, m, v, step, list(g), lr)

    _bitwise_equal(out_f[0], loss_and_g[0], "loss")
    for j in range(len(out_c)):
        _bitwise_equal(out_f[1 + j], out_c[j], f"out{j}")


def test_batched_io_matches_lowering_arity():
    """program_io / donated_input_slots stay in lock-step with the actual
    vmapped lowering (the same arity cross-check aot.py enforces)."""
    ac = tiny_ac()
    for runs in configs.BATCHED_RUN_COUNTS:
        for base in configs.BATCHED_BASES:
            program = f"{base}_batched{runs}"
            fn, args = model.program_factory(ac, program)
            ins, outs = model.program_io(ac, program)
            n_in = sum(len(a) if isinstance(a, (list, tuple)) else 1
                       for a in args)
            assert n_in == len(ins), program
            shaped = jax.eval_shape(fn, *args)
            flat = jax.tree_util.tree_leaves(shaped)
            assert len(flat) == len(outs), program
            for leaf, o in zip(flat, outs):
                assert list(leaf.shape) == o["shape"], (program, o["name"])
            donated = model.donated_input_slots(ac, program)
            assert all(0 <= s < len(ins) for s in donated), program
            # donated slots must name the stacked t/m/v state, never the
            # shared frozen base or the batch
            for s in donated:
                prefix = ins[s]["name"].split(":", 1)[0]
                assert prefix in ("t", "m", "v", "g"), (program, ins[s])


def test_programs_for_gating():
    """Batched variants exist only for non-Pallas LoRA artifacts."""
    assert any("_batched" in p for p in configs.programs_for(tiny_ac()))
    assert not any("_batched" in p
                   for p in configs.programs_for(tiny_ac(pallas=True)))
    assert not any("_batched" in p
                   for p in configs.programs_for(tiny_ac("full_all")))
    assert not any("_batched" in p
                   for p in configs.programs_for(tiny_ac("dora")))
    for p in configs.programs_for(tiny_ac()):
        parsed = model.batched_runs(p)
        if parsed is not None:
            assert parsed[0] in configs.BATCHED_BASES
            assert parsed[1] in configs.BATCHED_RUN_COUNTS
