"""L2 model invariants: adapter algebra, causality, masking, mode parity."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model
from tests.conftest import init_params, make_batch, tiny_ac


def _forward(ac, tr, fr, tok):
    return model.forward(ac, model.pack_params(ac, tr, fr), tok)


def test_lora_with_zero_b_matches_full_attn_forward():
    """Freshly-initialized LoRA (B=0) must compute exactly the base model."""
    rng = np.random.default_rng(0)
    ac_l = tiny_ac("lora")
    ac_f = tiny_ac("full_attn")
    tr_l = init_params(configs.trainable_spec(ac_l), rng)
    fr_l = init_params(configs.frozen_spec(ac_l), np.random.default_rng(1))
    tok, _, _ = make_batch(ac_l, rng, batch=2)

    # Build the full_attn param lists holding identical values.
    d_l = model.pack_params(ac_l, tr_l, fr_l)
    tr_f = [jnp.asarray(d_l[p.name]) for p in configs.trainable_spec(ac_f)]
    fr_f = [jnp.asarray(d_l[p.name]) for p in configs.frozen_spec(ac_f)]

    out_l = _forward(ac_l, tr_l, fr_l, tok)
    out_f = _forward(ac_f, tr_f, fr_f, tok)
    np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_f),
                               rtol=1e-5, atol=1e-5)


def test_lora_equals_merged_weights():
    """x@W0 + s(x@A)@B == x@(W0 + s·A@B) applied through the whole model."""
    rng = np.random.default_rng(2)
    ac = tiny_ac("lora")
    tr = init_params(configs.trainable_spec(ac), rng)
    # non-zero B so the adapters actually contribute
    tr = [t + jnp.asarray(np.random.default_rng(9).normal(0, 0.02, t.shape),
                          jnp.float32) for t in tr]
    fr = init_params(configs.frozen_spec(ac), np.random.default_rng(3))
    tok, _, _ = make_batch(ac, rng, batch=2)
    out = _forward(ac, tr, fr, tok)

    # merge adapters into the frozen weights, then run full_attn
    ac_f = tiny_ac("full_attn")
    d = model.pack_params(ac, tr, fr)
    merged = dict(d)
    for i in range(ac.model.n_layers):
        for w in configs.ADAPTED_MATRICES:
            nm = f"layer{i}.attn.{w}"
            merged[nm] = d[nm] + ac.lora_scale * (d[f"{nm}.lora_a"] @ d[f"{nm}.lora_b"])
    tr_f = [merged[p.name] for p in configs.trainable_spec(ac_f)]
    fr_f = [merged[p.name] for p in configs.frozen_spec(ac_f)]
    out_m = _forward(ac_f, tr_f, fr_f, tok)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_m),
                               rtol=1e-4, atol=1e-4)


def test_pallas_model_matches_jnp_model():
    """The use_pallas artifact variant computes the same function."""
    rng = np.random.default_rng(4)
    ac_j = tiny_ac("lora")
    ac_p = tiny_ac("lora", pallas=True)
    tr = init_params(configs.trainable_spec(ac_j), rng)
    tr = [t + 0.01 for t in tr]  # non-trivial adapters
    fr = init_params(configs.frozen_spec(ac_j), np.random.default_rng(5))
    tok, tgt, msk = make_batch(ac_j, rng, batch=2)
    out_j = _forward(ac_j, tr, fr, tok)
    out_p = _forward(ac_p, tr, fr, tok)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p),
                               rtol=1e-4, atol=1e-4)
    # gradients too (custom VJP path)
    g_j = jax.grad(lambda t: model.loss_fn(ac_j, t, fr, tok, tgt, msk))(tr)
    g_p = jax.grad(lambda t: model.loss_fn(ac_p, t, fr, tok, tgt, msk))(tr)
    for a, b in zip(g_j, g_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_dora_init_matches_base_forward():
    """DoRA with B=0 and m=colnorm(W0) equals the base model."""
    rng = np.random.default_rng(6)
    ac = tiny_ac("dora")
    fr = init_params(configs.frozen_spec(ac), np.random.default_rng(7))
    d_frozen = {p.name: arr for p, arr in zip(configs.frozen_spec(ac), fr)}
    tr = []
    for p in configs.trainable_spec(ac):
        if p.name.endswith("lora_b"):
            tr.append(jnp.zeros(p.shape, jnp.float32))
        elif p.name.endswith("dora_m"):
            w0 = d_frozen[p.name.rsplit(".", 1)[0]]
            tr.append(jnp.sqrt(jnp.sum(w0 * w0, axis=0)) + model.DORA_EPS)
        else:
            tr.append(jnp.asarray(rng.normal(0, 0.05, p.shape), jnp.float32))
    tok, _, _ = make_batch(ac, rng, batch=2)
    out = _forward(ac, tr, fr, tok)

    ac_f = tiny_ac("full_attn")
    dd = model.pack_params(ac, tr, fr)
    tr_f = [dd[p.name] for p in configs.trainable_spec(ac_f)]
    fr_f = [dd[p.name] for p in configs.frozen_spec(ac_f)]
    out_f = _forward(ac_f, tr_f, fr_f, tok)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_f),
                               rtol=2e-3, atol=2e-3)


def test_model_is_causal():
    rng = np.random.default_rng(8)
    ac = tiny_ac("lora")
    tr = init_params(configs.trainable_spec(ac), rng)
    fr = init_params(configs.frozen_spec(ac), np.random.default_rng(9))
    tok, _, _ = make_batch(ac, rng, batch=1)
    out = _forward(ac, tr, fr, tok)
    tok2 = tok.at[0, -1].set((int(tok[0, -1]) + 1) % ac.model.vocab_size)
    out2 = _forward(ac, tr, fr, tok2)
    np.testing.assert_allclose(np.asarray(out[0, :-1]), np.asarray(out2[0, :-1]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out[0, -1]), np.asarray(out2[0, -1]))


def test_masked_loss_ignores_masked_positions():
    rng = np.random.default_rng(10)
    ac = tiny_ac("lora")
    tr = init_params(configs.trainable_spec(ac), rng)
    fr = init_params(configs.frozen_spec(ac), np.random.default_rng(11))
    tok, tgt, msk = make_batch(ac, rng, batch=2)
    half = msk.at[:, : ac.model.seq_len // 2].set(0.0)
    l1 = model.loss_fn(ac, tr, fr, tok, tgt, half)
    # changing targets in the masked region must not change the loss
    tgt2 = tgt.at[:, 0].set((tgt[:, 0] + 3) % ac.model.vocab_size)
    l2 = model.loss_fn(ac, tr, fr, tok, tgt2, half)
    assert float(jnp.abs(l1 - l2)) < 1e-7


def test_masked_loss_all_zero_mask_is_finite():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    tgt = jnp.zeros((1, 4), jnp.int32)
    msk = jnp.zeros((1, 4), jnp.float32)
    assert float(model.masked_loss(logits, tgt, msk)) == 0.0


def test_uniform_logits_loss_is_log_vocab():
    ac = tiny_ac("lora")
    v = ac.model.vocab_size
    logits = jnp.zeros((2, 3, v), jnp.float32)
    tgt = jnp.zeros((2, 3), jnp.int32)
    msk = jnp.ones((2, 3), jnp.float32)
    np.testing.assert_allclose(float(model.masked_loss(logits, tgt, msk)),
                               np.log(v), rtol=1e-5)


@pytest.mark.parametrize("mode", configs.TRAIN_MODES)
def test_grad_step_plus_adam_apply_equals_train_step(mode):
    """The accumulation path and the fused path must agree bit-for-bit-ish."""
    rng = np.random.default_rng(12)
    ac = tiny_ac(mode)
    tr = init_params(configs.trainable_spec(ac), rng)
    fr = init_params(configs.frozen_spec(ac), np.random.default_rng(13))
    m = [jnp.zeros_like(t) for t in tr]
    v = [jnp.zeros_like(t) for t in tr]
    tok, tgt, msk = make_batch(ac, rng)
    step = jnp.asarray(3.0, jnp.float32)
    lr = jnp.asarray(1e-3, jnp.float32)

    ts_fn, _ = model.make_train_step(ac)
    gs_fn, _ = model.make_grad_step(ac)
    aa_fn, _ = model.make_adam_apply(ac)
    fused = jax.jit(ts_fn)(tr, m, v, step, fr, tok, tgt, msk, lr)
    g_out = jax.jit(gs_fn)(tr, fr, tok, tgt, msk)
    grads = list(g_out[1:])
    split = jax.jit(aa_fn)(tr, m, v, step, grads, lr)
    n = len(tr)
    np.testing.assert_allclose(float(fused[0]), float(g_out[0]), rtol=1e-6)
    for a, b in zip(split[:n], fused[1:1 + n]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_training_reduces_loss_all_modes():
    for mode in configs.TRAIN_MODES:
        rng = np.random.default_rng(14)
        ac = tiny_ac(mode)
        tr = init_params(configs.trainable_spec(ac), rng)
        fr = init_params(configs.frozen_spec(ac), np.random.default_rng(15))
        m = [jnp.zeros_like(t) for t in tr]
        v = [jnp.zeros_like(t) for t in tr]
        tok, tgt, msk = make_batch(ac, rng)
        fn = jax.jit(model.make_train_step(ac)[0])
        lr = jnp.asarray(1e-2, jnp.float32)
        losses = []
        for i in range(6):
            out = fn(tr, m, v, jnp.asarray(float(i), jnp.float32), fr,
                     tok, tgt, msk, lr)
            losses.append(float(out[0]))
            n = len(tr)
            tr = list(out[1:1 + n])
            m = list(out[1 + n:1 + 2 * n])
            v = list(out[1 + 2 * n:1 + 3 * n])
        assert losses[-1] < losses[0], (mode, losses)
