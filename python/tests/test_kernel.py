"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core correctness signal for the kernel layer (DESIGN.md §L1).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lora_matmul import (lora_matmul, lora_matmul_batched,
                                         mxu_utilization_estimate,
                                         vmem_footprint_bytes)
from compile.kernels.ref import (causal_attention_ref, dora_matmul_ref,
                                 lora_matmul_ref)

DIMS = st.integers(min_value=1, max_value=96)
RANKS = st.integers(min_value=1, max_value=16)


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(0, 1, shape), dtype)


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, r=RANKS, scale=st.floats(0.0, 4.0))
def test_lora_matmul_matches_ref_f32(m, k, n, r, scale):
    rng = np.random.default_rng(m * 7919 + k * 104729 + n * 31 + r)
    x, w0 = _rand(rng, m, k), _rand(rng, k, n)
    a, b = _rand(rng, k, r), _rand(rng, r, n)
    got = lora_matmul(x, w0, a, b, scale)
    want = lora_matmul_ref(x, w0, a, b, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 48),
       r=st.integers(1, 8))
def test_lora_matmul_matches_ref_bf16(m, k, n, r):
    rng = np.random.default_rng(m + 1000 * k + n)
    x, w0 = _rand(rng, m, k, dtype=jnp.bfloat16), _rand(rng, k, n, dtype=jnp.bfloat16)
    a, b = _rand(rng, k, r, dtype=jnp.bfloat16), _rand(rng, r, n, dtype=jnp.bfloat16)
    got = np.asarray(lora_matmul(x, w0, a, b, 1.0), np.float32)
    want = np.asarray(lora_matmul_ref(x.astype(jnp.float32), w0.astype(jnp.float32),
                                      a.astype(jnp.float32), b.astype(jnp.float32),
                                      1.0))
    # bf16 inputs, f32 accumulate: tolerance scales with K.
    np.testing.assert_allclose(got, want, rtol=0.1, atol=0.1 * np.sqrt(k))


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 8), (128, 128, 128),
                                    (7, 13, 5)])
def test_lora_matmul_block_shapes(blocks):
    """Result must be independent of the tiling schedule."""
    bm, bn, bk = blocks
    rng = np.random.default_rng(42)
    x, w0 = _rand(rng, 32, 48), _rand(rng, 48, 64)
    a, b = _rand(rng, 48, 8), _rand(rng, 8, 64)
    got = lora_matmul(x, w0, a, b, 2.0, block_m=bm, block_n=bn, block_k=bk)
    want = lora_matmul_ref(x, w0, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_lora_matmul_zero_b_is_base_matmul():
    rng = np.random.default_rng(0)
    x, w0 = _rand(rng, 16, 32), _rand(rng, 32, 24)
    a = _rand(rng, 32, 4)
    b = jnp.zeros((4, 24), jnp.float32)
    got = lora_matmul(x, w0, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w0),
                               rtol=1e-5, atol=1e-5)


def test_lora_matmul_batched_flattens_leading_dims():
    rng = np.random.default_rng(1)
    x = _rand(rng, 2, 3, 16)
    w0, a, b = _rand(rng, 16, 8), _rand(rng, 16, 2), _rand(rng, 2, 8)
    got = lora_matmul_batched(x, w0, a, b, 0.5)
    assert got.shape == (2, 3, 8)
    want = lora_matmul_ref(np.asarray(x).reshape(6, 16), w0, a, b, 0.5)
    np.testing.assert_allclose(np.asarray(got).reshape(6, 8), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_lora_matmul_under_jit_and_grad_via_ref_parity():
    """The kernel must be usable inside jit (as the L2 model uses it)."""
    rng = np.random.default_rng(3)
    x, w0 = _rand(rng, 8, 16), _rand(rng, 16, 16)
    a, b = _rand(rng, 16, 4), _rand(rng, 4, 16)
    f = jax.jit(lambda *args: lora_matmul(*args, 1.0))
    np.testing.assert_allclose(np.asarray(f(x, w0, a, b)),
                               np.asarray(lora_matmul_ref(x, w0, a, b, 1.0)),
                               rtol=1e-5, atol=1e-5)


def test_dora_ref_reduces_to_base_when_b_zero_and_m_colnorm():
    """DoRA with B=0 and m=||W0||_col must equal the base projection."""
    rng = np.random.default_rng(5)
    x, w0 = _rand(rng, 8, 16), _rand(rng, 16, 12)
    a = _rand(rng, 16, 4)
    b = jnp.zeros((4, 12), jnp.float32)
    m = jnp.sqrt(jnp.sum(w0 * w0, axis=0)) + 1e-6
    got = dora_matmul_ref(x, w0, a, b, m, 2.0, eps=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w0),
                               rtol=1e-4, atol=1e-4)


def test_causal_attention_ref_is_causal():
    rng = np.random.default_rng(7)
    q, k, v = _rand(rng, 8, 4), _rand(rng, 8, 4), _rand(rng, 8, 4)
    base = causal_attention_ref(q, k, v)
    k2 = k.at[-1].set(99.0)
    v2 = v.at[-1].set(99.0)
    pert = causal_attention_ref(q, k2, v2)
    # all rows except the last must be unchanged
    np.testing.assert_allclose(np.asarray(base[:-1]), np.asarray(pert[:-1]),
                               rtol=1e-6, atol=1e-6)


def test_vmem_footprint_monotone_in_blocks():
    small = vmem_footprint_bytes(32, 32, 32, 8)
    big = vmem_footprint_bytes(128, 128, 128, 8)
    assert small < big
    # r=64 LoRA tile set must fit VMEM (~16 MiB/core budget, use half)
    assert vmem_footprint_bytes(128, 128, 128, 64) < 8 * 1024 * 1024


def test_mxu_utilization_estimate_full_tiles():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(64, 128, 128) == 0.5
