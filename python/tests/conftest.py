"""Shared fixtures/helpers for the python test-suite."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile import configs


def init_params(spec, rng, std=0.05):
    """Host-side reference initializer (mirrors rust/src/model/init.rs):
    lora_b → zeros, LN scale → ones, LN bias → zeros, else normal(0, std)."""
    out = []
    for p in spec:
        if p.name.endswith("lora_b"):
            arr = np.zeros(p.shape, np.float32)
        elif ".ln" in p.name or p.name.startswith("final_ln"):
            if p.name.endswith("scale"):
                arr = np.ones(p.shape, np.float32)
            else:
                arr = np.zeros(p.shape, np.float32)
        elif p.name.endswith("dora_m"):
            arr = np.ones(p.shape, np.float32)  # overwritten by col-norms in real init
        else:
            arr = rng.normal(0, std, p.shape).astype(np.float32)
        out.append(jnp.asarray(arr))
    return out


def make_batch(ac, rng, batch=None):
    b = batch or ac.model.micro_batch
    t = ac.model.seq_len
    v = ac.model.vocab_size
    tok = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
    msk = jnp.ones((b, t), jnp.float32)
    return tok, tgt, msk


def tiny_ac(mode="lora", rank=4, pallas=False):
    return configs.ArtifactConfig(configs.MODELS["ff-tiny"], mode,
                                  lora_rank=rank, use_pallas=pallas)
