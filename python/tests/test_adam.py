"""Adam math: the L2 update must match an independent numpy implementation
(the same math rust/src/optim/adam.rs implements)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.configs import ADAM_BETA1, ADAM_BETA2, ADAM_EPS
from compile.model import adam_update


def numpy_adam(w, m, v, step, g, lr):
    step1 = step + 1.0
    m2 = ADAM_BETA1 * m + (1 - ADAM_BETA1) * g
    v2 = ADAM_BETA2 * v + (1 - ADAM_BETA2) * g * g
    mhat = m2 / (1 - ADAM_BETA1 ** step1)
    vhat = v2 / (1 - ADAM_BETA2 ** step1)
    return w - lr * mhat / (np.sqrt(vhat) + ADAM_EPS), m2, v2


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 64), step=st.integers(0, 10000),
       lr=st.floats(1e-6, 1e-1), seed=st.integers(0, 2**31))
def test_adam_matches_numpy(n, step, lr, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, n).astype(np.float32)
    m = rng.normal(0, 0.1, n).astype(np.float32)
    v = np.abs(rng.normal(0, 0.01, n)).astype(np.float32)
    g = rng.normal(0, 1, n).astype(np.float32)
    got_w, got_m, got_v = adam_update(
        [jnp.asarray(w)], [jnp.asarray(m)], [jnp.asarray(v)],
        jnp.asarray(float(step), jnp.float32), [jnp.asarray(g)],
        jnp.asarray(lr, jnp.float32))
    want_w, want_m, want_v = numpy_adam(
        w.astype(np.float64), m.astype(np.float64), v.astype(np.float64),
        float(step), g.astype(np.float64), lr)
    np.testing.assert_allclose(np.asarray(got_m[0]), want_m, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_v[0]), want_v, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(got_w[0]), want_w, rtol=1e-4,
                               atol=1e-5)


def test_adam_first_step_is_sign_sgd_scaled():
    """At step 0 with zero state, Adam ≈ lr·sign(g) (bias correction)."""
    g = np.array([0.5, -2.0, 3.0], np.float32)
    w = np.zeros(3, np.float32)
    got_w, _, _ = adam_update(
        [jnp.asarray(w)], [jnp.zeros(3)], [jnp.zeros(3)],
        jnp.asarray(0.0, jnp.float32), [jnp.asarray(g)],
        jnp.asarray(0.1, jnp.float32))
    np.testing.assert_allclose(np.asarray(got_w[0]), -0.1 * np.sign(g),
                               rtol=1e-3)


def test_adam_zero_grad_keeps_weights_when_state_zero():
    w = np.array([1.0, -1.0], np.float32)
    got_w, got_m, got_v = adam_update(
        [jnp.asarray(w)], [jnp.zeros(2)], [jnp.zeros(2)],
        jnp.asarray(5.0, jnp.float32), [jnp.zeros(2)],
        jnp.asarray(0.1, jnp.float32))
    np.testing.assert_allclose(np.asarray(got_w[0]), w, atol=1e-7)
