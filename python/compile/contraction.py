"""Analytic contraction-order selection for LoRA matmul chains.

"Run LoRA Run" (arXiv:2312.03415) observes that the LoRA chain
``x·A·B`` (x: [M,K], A: [K,r], B: [r,N]) admits two contraction orders
whose FLOP costs cross over as a function of (M, K, N, r):

  * **factored** ``(x·A)·B`` — the textbook LoRA order, cheap when the
    rank is small relative to the model width;
  * **merged**  ``x·(A·B)`` — materialize ``W_lora = A·B`` once per call
    and apply it like a dense matrix, cheap when r approaches K/N (the
    full-rank sweep points, r = d_model).

The backward pass has the analogous pair (the merged backward routes the
adapter gradients through the ``G = xᵀ·g`` [K,N] intermediate instead of
the [M,r] activations). This module is the single source of truth for the
cost formulas and the argmin chooser; ``aot.py`` records the chosen order
per program in the manifest, and ``rust/src/flops`` mirrors these exact
formulas so runtime FLOP accounting matches what the HLO actually does.

``W_lora`` is **never** folded into ``W0``: the base matrix is a shared,
frozen buffer (uploaded once and reused across every run batched over the
same artifact — see docs/transfer-contract.md §5), so the merged order
adds ``x·W_lora`` as a second dense matmul instead of mutating ``W0``.

All costs use the 2·(multiply-add) convention of the rust FLOP model and
count only the *adapter* work — the base ``x·W0`` term (2·M·K·N) is
identical under both orders and stays in the base model's accounting.
"""

from __future__ import annotations

FACTORED = "factored"
MERGED = "merged"
ORDERS = (FACTORED, MERGED)


def forward_flops(order: str, m: int, k: int, n: int, r: int) -> int:
    """Adapter-only forward cost of one projection, excluding ``x·W0``.

    factored: ``(x·A)·B``          → 2·M·r·K + 2·M·r·N
    merged:   ``W_l=A·B; x·W_l``   → 2·K·r·N + 2·M·K·N
    """
    if order == FACTORED:
        return 2 * m * r * (k + n)
    assert order == MERGED, order
    return 2 * k * r * n + 2 * m * k * n


def backward_flops(order: str, m: int, k: int, n: int, r: int) -> int:
    """Adapter backward cost (dA, dB, and the adapter term of dx).

    factored (the legacy VJP):
      ``gb = g·Bᵀ`` (2MNr), ``dx += gb·Aᵀ`` (2MKr),
      ``dA = xᵀ·gb`` (2MKr), ``dB = (x·A)ᵀ·g`` (2MKr + 2MNr)
      → 2·M·r·(3K + 2N)
    merged (via the [K,N] intermediate ``G = xᵀ·g``):
      ``G`` (2MKN), ``dA = G·Bᵀ`` (2KrN), ``dB = Aᵀ·G`` (2KrN),
      dx stays factored: ``(g·Bᵀ)·Aᵀ`` (2MNr + 2MKr)
      → 2·M·K·N + 4·K·r·N + 2·M·r·(K + N)
    """
    if order == FACTORED:
        return 2 * m * r * (3 * k + 2 * n)
    assert order == MERGED, order
    return 2 * m * k * n + 4 * k * r * n + 2 * m * r * (k + n)


def choose_forward(m: int, k: int, n: int, r: int) -> str:
    """Argmin of ``forward_flops`` over the two orders (tie → factored,
    the legacy order, so old artifacts re-emit unchanged)."""
    if forward_flops(MERGED, m, k, n, r) < forward_flops(FACTORED, m, k, n, r):
        return MERGED
    return FACTORED


def choose_backward(m: int, k: int, n: int, r: int) -> str:
    """Argmin of ``backward_flops`` over the two orders (tie → factored)."""
    if backward_flops(MERGED, m, k, n, r) < backward_flops(FACTORED, m, k, n, r):
        return MERGED
    return FACTORED


def choose_orders(m: int, k: int, n: int, r: int) -> dict:
    """Both chosen orders for one projection shape, manifest-ready."""
    return {
        "forward": choose_forward(m, k, n, r),
        "backward": choose_backward(m, k, n, r),
    }
