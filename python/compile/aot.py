"""AOT pipeline: lower every (config, program) pair to HLO text + manifest.

HLO *text* (not ``lowered.compile().serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
runtime's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser on the rust side reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts            # default set
    python -m compile.aot --out-dir ../artifacts --smoke    # CI-fast set
    python -m compile.aot --out-dir ../artifacts --only ff-tiny_lora_r8

Incremental: a (config, program) is re-lowered only if its .hlo.txt is
missing or any compile/ source is newer (make drives this at the directory
level too). ``index.json`` lists every emitted artifact so the rust side can
enumerate what exists without globbing.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
import warnings

import jax

from compile import configs, model
from compile.configs import (ADAM_BETA1, ADAM_BETA2, ADAM_EPS, ArtifactConfig,
                             frozen_spec, trainable_spec)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def alias_count(hlo_text: str) -> int:
    """Entries in the module's ``input_output_alias`` map (one
    ``may-alias``/``must-alias`` marker per aliased output)."""
    return hlo_text.count("may-alias") + hlo_text.count("must-alias")


def manifest_for(ac: ArtifactConfig) -> dict:
    m = ac.model
    return {
        "format_version": 1,
        "key": ac.key,
        "config": {
            "model": m.name,
            "vocab_size": m.vocab_size,
            "d_model": m.d_model,
            "n_layers": m.n_layers,
            "n_heads": m.n_heads,
            "seq_len": m.seq_len,
            "micro_batch": m.micro_batch,
            "eval_batch": m.eval_batch,
            "train_mode": ac.train_mode,
            "lora_rank": ac.lora_rank,
            "lora_alpha": ac.lora_alpha,
            "lora_scale": ac.lora_scale,
            "use_pallas": ac.use_pallas,
        },
        "adam": {"beta1": ADAM_BETA1, "beta2": ADAM_BETA2, "eps": ADAM_EPS},
        "trainable": [{"name": p.name, "shape": list(p.shape)}
                      for p in trainable_spec(ac)],
        "frozen": [{"name": p.name, "shape": list(p.shape)}
                   for p in frozen_spec(ac)],
        "programs": {},
    }


def content_hash(manifest: dict, adir: str) -> str:
    """Canonical artifact content hash, shared with rust/src/store.

    sha256 over the canonical manifest bytes (``json.dumps(..., indent=1)``
    of the manifest *without* its ``content_hash`` key — i.e. exactly the
    bytes that land in manifest.json minus the stamp), then for each
    program file in program-name-sorted order ``\\0<file name>\\0<file
    bytes>``. Field ordering is stable because ``manifest_for`` builds the
    dict in a fixed insertion order and ``json.dump`` preserves it.
    """
    body = {k: v for k, v in manifest.items() if k != "content_hash"}
    h = hashlib.sha256(json.dumps(body, indent=1).encode())
    for program in sorted(body["programs"]):
        fname = body["programs"][program]["file"]
        h.update(b"\0" + fname.encode() + b"\0")
        with open(os.path.join(adir, fname), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def stamp_content_hash(manifest: dict, adir: str) -> None:
    """Record the content hash as the manifest's *last* top-level key, so
    a stamped manifest.json always ends with ``,\\n "content_hash":
    "<hex>"\\n}`` — the rust store recovers the canonical pre-stamp bytes
    by stripping exactly that suffix (store::split_recorded)."""
    manifest.pop("content_hash", None)
    manifest["content_hash"] = content_hash(manifest, adir)


def verify_stamp(adir: str) -> None:
    """Emit-time self-check of the suffix contract: reconstruct the
    canonical bytes from the written manifest.json the way the rust reader
    does, recompute, and require a match. Any drift in the emitter's JSON
    formatting fails here, never at artifact-load time on another host."""
    path = os.path.join(adir, "manifest.json")
    with open(path) as f:
        text = f.read()
    manifest = json.loads(text)
    recorded = manifest["content_hash"]
    suffix = ',\n "content_hash": "%s"\n}' % recorded
    assert text.endswith(suffix), f"{path}: stamp is not the trailing key"
    canonical = text[: -len(suffix)] + "\n}"
    body = {k: v for k, v in manifest.items() if k != "content_hash"}
    assert canonical == json.dumps(body, indent=1), \
        f"{path}: canonical bytes do not round-trip"
    assert content_hash(manifest, adir) == recorded, \
        f"{path}: content_hash does not match directory contents"


def emit_artifact(ac: ArtifactConfig, out_dir: str, force: bool = False) -> dict:
    """Lower all programs for one config; returns its index entry."""
    adir = os.path.join(out_dir, ac.key)
    os.makedirs(adir, exist_ok=True)
    manifest = manifest_for(ac)
    src_mtime = max(
        os.path.getmtime(os.path.join(os.path.dirname(__file__), f))
        for f in ("model.py", "configs.py", "aot.py", "contraction.py",
                  os.path.join("kernels", "lora_matmul.py"),
                  os.path.join("kernels", "ref.py")))

    for program in configs.programs_for(ac):
        hlo_path = os.path.join(adir, f"{program}.hlo.txt")
        ins, outs = model.program_io(ac, program)
        donated = model.donated_input_slots(ac, program)
        entry = {
            "file": f"{program}.hlo.txt",
            "inputs": ins,
            "outputs": outs,
            # Flattened input-slot indices the executable donates. The rust
            # runtime rejects borrowed-input execution of donating programs
            # and requires these slots to be passed by value.
            "donated_inputs": donated,
        }
        # Per-shape contraction orders the traced HLO actually uses
        # (contraction.py chooser); rust/src/flops consumes these so FLOP
        # accounting matches the emitted program, not an assumed order.
        orders = model.program_orders(ac, program)
        if orders is not None:
            entry["lora_orders"] = orders
        parsed = model.batched_runs(program)
        if parsed is not None:
            entry["batch_runs"] = parsed[1]
        manifest["programs"][program] = entry
        # Every donated slot with a matching output must survive as an
        # alias map entry; adam_apply donates n more inputs (the grads)
        # than it has outputs, so its expectation caps at the output count.
        expect_aliases = min(len(donated), len(outs))
        if (not force and os.path.exists(hlo_path)
                and os.path.getmtime(hlo_path) >= src_mtime):
            # The manifest above claims `donated` for this executable —
            # trust the cache only if the HLO on disk actually aliases what
            # the claim implies (guards against artifacts copied/touched
            # across checkouts with a different PROGRAM_DONATE).
            with open(hlo_path) as f:
                cached_aliases = alias_count(f.read())
            if cached_aliases == expect_aliases:
                print(f"  [cached] {ac.key}/{program}")
                continue
            print(f"  [stale-alias] {ac.key}/{program}: HLO has "
                  f"{cached_aliases} aliases, manifest implies "
                  f"{expect_aliases} — re-lowering")
        t0 = time.time()
        fn, args = model.program_factory(ac, program)
        donate = model.program_donate(program)
        with warnings.catch_warnings():
            if len(donated) > len(outs):
                # adam_apply only: more donated inputs (t/m/v/g) than
                # outputs, so the unused-donation warning is expected. For
                # every other program that warning is a real lowering bug
                # and stays fatal via the alias-count assert below.
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        text = to_hlo_text(lowered)
        assert alias_count(text) == expect_aliases, (
            ac.key, program, alias_count(text), expect_aliases,
            "donation did not fully survive HLO-text lowering")
        # Cross-check: the flattened lowering arity must match the manifest.
        n_in = sum(len(a) if isinstance(a, (list, tuple)) else 1 for a in args)
        assert n_in == len(ins), (ac.key, program, n_in, len(ins))
        with open(hlo_path, "w") as f:
            f.write(text)
        print(f"  [lowered] {ac.key}/{program} "
              f"({len(text) / 1e6:.2f} MB, {time.time() - t0:.1f}s)")

    stamp_content_hash(manifest, adir)
    with open(os.path.join(adir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    verify_stamp(adir)
    return {"key": ac.key, "dir": ac.key, "model": ac.model.name,
            "train_mode": ac.train_mode, "lora_rank": ac.lora_rank,
            "use_pallas": ac.use_pallas,
            "n_params": ac.model.n_params(),
            "n_trainable": configs.n_trainable(ac)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--smoke", action="store_true",
                    help="emit only the CI-fast artifact set")
    ap.add_argument("--only", action="append", default=None,
                    help="emit only artifact keys matching this substring")
    ap.add_argument("--force", action="store_true", help="ignore mtime cache")
    args = ap.parse_args()

    acs = (configs.smoke_artifact_set() if args.smoke
           else configs.default_artifact_set())
    if args.only:
        acs = [ac for ac in acs
               if any(pat in ac.key for pat in args.only)]
        if not acs:
            print(f"no artifact matches {args.only}", file=sys.stderr)
            sys.exit(1)

    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()
    index = []
    for ac in acs:
        print(f"[config] {ac.key}: {ac.model.n_params() / 1e6:.2f}M params, "
              f"{configs.n_trainable(ac) / 1e3:.1f}K trainable")
        index.append(emit_artifact(ac, args.out_dir, force=args.force))

    # Merge with any pre-existing index entries (incremental --only runs).
    index_path = os.path.join(args.out_dir, "index.json")
    merged = {e["key"]: e for e in index}
    if os.path.exists(index_path):
        with open(index_path) as f:
            for e in json.load(f)["artifacts"]:
                merged.setdefault(e["key"], e)
    with open(index_path, "w") as f:
        json.dump({"format_version": 1,
                   "artifacts": sorted(merged.values(), key=lambda e: e["key"])},
                  f, indent=1)
    print(f"done: {len(index)} artifact configs in {time.time() - t0:.1f}s "
          f"→ {index_path}")


if __name__ == "__main__":
    main()
