"""Pallas fused LoRA matmul kernel — the paper's compute hot-spot.

The LoRA forward is ``y = x @ w0 + scale * (x @ a) @ b``. On GPU (the
paper's hardware) this is two tensor-core GEMMs with the rank-r update
resident in L2. The TPU restructuring (DESIGN.md §Hardware-Adaptation):

  * output-stationary grid over (M/bm, N/bn) tiles with a K-reduction axis;
  * ``w0`` tiles stream HBM→VMEM block by block via BlockSpec;
  * the low-rank factors ``a`` (K×r) and ``b`` (r×N) are *VMEM-resident*
    per grid step — for r ≤ 64 a (bk×r) + (r×bn) slice is a few KB, so the
    rank-r update rides along with the streaming GEMM for free;
  * block sizes default to MXU-shaped multiples (≤128) clamped to the
    problem size; accumulation is f32 regardless of input dtype.

Identity used for fusion: ``(x @ a) @ b == Σ_k (x_k @ a_k) @ b`` — the
K-reduction distributes over the first matmul only, so each grid step can
add its own ``(x_blk @ a_blk) @ b_blk`` partial into the accumulator.

``interpret=True`` is mandatory on CPU: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is ≤ preferred (MXU-friendly)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _kernel(x_ref, w0_ref, a_ref, b_ref, o_ref, *, scale: float, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w0 = w0_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # Streaming GEMM partial + the fused low-rank partial for this K block.
    acc = x @ w0 + scale * ((x @ a) @ b)
    o_ref[...] += acc.astype(o_ref.dtype)


def lora_matmul(x, w0, a, b, scale, *, block_m: int = 128, block_n: int = 128,
                block_k: int = 128, interpret: bool = True):
    """Fused ``x @ w0 + scale * (x @ a) @ b`` as a Pallas kernel.

    Shapes: x ``[M, K]``, w0 ``[K, N]``, a ``[K, r]``, b ``[r, N]`` → ``[M, N]``.
    Block sizes are clamped to divisors of the problem dims so arbitrary
    (hypothesis-generated) shapes work without padding.
    """
    m, k = x.shape
    k2, n = w0.shape
    assert k == k2, (x.shape, w0.shape)
    r = a.shape[1]
    assert a.shape == (k, r) and b.shape == (r, n), (a.shape, b.shape)

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    grid = (m // bm, n // bn, k // bk)

    return pl.pallas_call(
        functools.partial(_kernel, scale=float(scale), k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # x: stream K
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # w0: stream K
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),    # a: K slice, resident r
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),     # b: resident r
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w0, a, b)


def lora_matmul_batched(x, w0, a, b, scale, **kw):
    """Apply :func:`lora_matmul` to ``x`` of shape ``[..., K]`` by flattening
    the leading dims into M — the form the L2 model uses."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    y = lora_matmul(x.reshape((-1, k)), w0, a, b, scale, **kw)
    return y.reshape(lead + (w0.shape[1],))


def vmem_footprint_bytes(block_m: int, block_n: int, block_k: int, r: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set for one grid step (DESIGN.md §Perf):
    x, w0 tiles + resident a, b slices + f32 accumulator."""
    tiles = block_m * block_k + block_k * block_n + block_k * r + r * block_n
    return tiles * dtype_bytes + block_m * block_n * 4


def mxu_utilization_estimate(block_m: int, block_n: int, block_k: int) -> float:
    """Fraction of 128×128 MXU lanes occupied by the chosen tile shape."""
    return min(block_m, 128) * min(block_n, 128) / (128.0 * 128.0) * min(
        block_k, 128) / 128.0
