"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float tolerance under pytest (``tests/test_kernel.py``
sweeps shapes and dtypes with hypothesis). They are also the default compute
path for model artifacts — XLA:CPU fuses the two matmuls well, while the
Pallas kernel exists to express the TPU HBM→VMEM schedule (DESIGN.md
§Hardware-Adaptation) and is lowered with ``interpret=True`` for CPU.
"""

from __future__ import annotations

import jax.numpy as jnp


def lora_matmul_ref(x, w0, a, b, scale):
    """``y = x @ w0 + scale * (x @ a) @ b``.

    Shapes: x ``[M, K]``, w0 ``[K, N]``, a ``[K, r]``, b ``[r, N]``.
    The low-rank product is evaluated as two skinny matmuls — never
    materializing ``w0 + scale * a @ b`` — which is the whole point of LoRA.
    """
    return x @ w0 + scale * ((x @ a) @ b)


def dora_matmul_ref(x, w0, a, b, m, scale, eps: float = 1e-6):
    """DoRA (Liu et al., 2024): magnitude/direction decomposition.

    ``W' = m ⊙ column_normalize(w0 + scale * a @ b)`` with the column norm
    taken over the input dimension (axis 0), then ``y = x @ W'``.
    """
    w = w0 + scale * (a @ b)
    norm = jnp.sqrt(jnp.sum(w * w, axis=0, keepdims=True)) + eps
    return x @ (w * (m[None, :] / norm))


def causal_attention_ref(q, k, v):
    """Plain causal attention for one head: softmax(qkᵀ/√dh + mask) v.

    Shapes: q, k, v ``[T, dh]``; returns ``[T, dh]``.
    """
    t, dh = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, q.dtype))
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return probs @ v
