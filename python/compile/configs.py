"""Model / training configurations and the artifact registry.

This file is the single source of truth on the python side for:
  * model architecture hyper-parameters (``ModelConfig``),
  * which (config, train-mode, rank) artifacts ``aot.py`` must emit,
  * program names and batch shapes.

The rust side derives the identical parameter spec in
``rust/src/model/spec.rs`` and cross-checks it against each artifact's
``manifest.json`` at load time, so any drift between the two languages is
caught before a single step runs.

Paper mapping (DESIGN.md §Substitutions): ff-tiny ↔ Pythia-1.4B,
ff-small ↔ Pythia-2.8B, ff-medium ↔ Pythia-6.9B, ff-large ↔ Llama-3-8B.
``ff-xl`` (~110M params) exists for the end-to-end example driver only.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

TRAIN_MODES = ("lora", "dora", "full_attn", "full_all")

# Adam hyper-parameters (paper Appendix E uses framework defaults).
ADAM_BETA1 = 0.9
ADAM_BETA2 = 0.999
ADAM_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one GPT-style model.

    All matmul weights are stored as ``[d_in, d_out]`` and applied as
    ``y = x @ W`` (no biases outside LayerNorm).
    """

    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    micro_batch: int
    eval_batch: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def n_params(self) -> int:
        """Total base parameter count (embeddings + blocks + head)."""
        d, v, t = self.d_model, self.vocab_size, self.seq_len
        per_layer = (
            4 * d * d          # wq wk wv wo
            + 2 * d * self.d_ff  # mlp in/out
            + 4 * d            # 2 LayerNorms (scale+bias)
        )
        return v * d + t * d + self.n_layers * per_layer + 2 * d + d * v


MODELS: Dict[str, ModelConfig] = {
    m.name: m
    for m in [
        ModelConfig("ff-tiny", vocab_size=512, d_model=64, n_layers=2,
                    n_heads=2, seq_len=64, micro_batch=8),
        ModelConfig("ff-small", vocab_size=1024, d_model=128, n_layers=4,
                    n_heads=4, seq_len=64, micro_batch=8),
        ModelConfig("ff-medium", vocab_size=2048, d_model=256, n_layers=6,
                    n_heads=8, seq_len=128, micro_batch=4),
        ModelConfig("ff-large", vocab_size=4096, d_model=384, n_layers=8,
                    n_heads=8, seq_len=128, micro_batch=2),
        ModelConfig("ff-xl", vocab_size=8192, d_model=768, n_layers=12,
                    n_heads=12, seq_len=256, micro_batch=1),
    ]
}


@dataclasses.dataclass(frozen=True)
class ArtifactConfig:
    """One artifact directory == one (model, train-mode, rank) triple."""

    model: ModelConfig
    train_mode: str  # lora | dora | full_attn | full_all
    lora_rank: int = 8
    lora_alpha: float = 16.0
    use_pallas: bool = False

    @property
    def key(self) -> str:
        """Directory name under artifacts/."""
        parts = [self.model.name, self.train_mode]
        if self.train_mode in ("lora", "dora"):
            parts.append(f"r{self.lora_rank}")
        if self.use_pallas:
            parts.append("pallas")
        return "_".join(parts)

    @property
    def lora_scale(self) -> float:
        return self.lora_alpha / float(self.lora_rank)


PROGRAMS = ("train_step", "grad_step", "grad_accum", "grad_finalize",
            "adam_apply", "eval_loss", "loft_realign")

# Group sizes for the batched multi-run program variants. The queue packs
# the largest R ≤ (number of eligible queued runs); exact group sizes
# only, no padding — a run that misses a group just executes solo.
BATCHED_RUN_COUNTS = (2, 4)

# Program bases that get a ``_batched{R}`` variant (see model.py).
BATCHED_BASES = ("train_step", "grad_step", "adam_apply", "eval_loss")


def programs_for(ac: ArtifactConfig) -> Tuple[str, ...]:
    """Every program name ``ac``'s artifact emits: the seven solo programs,
    plus ``{base}_batched{R}`` variants for non-Pallas LoRA artifacts
    (the only mode where queued runs share a frozen base worth stacking;
    the Pallas variant is an interpret-mode debugging reference)."""
    names = list(PROGRAMS)
    if ac.train_mode == "lora" and not ac.use_pallas:
        for r in BATCHED_RUN_COUNTS:
            names.extend(f"{base}_batched{r}" for base in BATCHED_BASES)
    return tuple(names)


def _ac(model: str, mode: str, rank: int = 8, pallas: bool = False) -> ArtifactConfig:
    return ArtifactConfig(MODELS[model], mode, lora_rank=rank, use_pallas=pallas)


def default_artifact_set() -> List[ArtifactConfig]:
    """Every artifact the experiment suite needs (DESIGN.md experiment index)."""
    out: List[ArtifactConfig] = []
    # fig2/3/4/9: model-size grid, LoRA + DoRA at r=8.
    for m in ("ff-tiny", "ff-small", "ff-medium", "ff-large"):
        out.append(_ac(m, "lora"))
        out.append(_ac(m, "dora"))
    # fig7: rank sweep on the smallest model (paper: Pythia-1.4B, r=1..64).
    for r in (1, 2, 4, 8, 16, 32, 64):
        if r != 8:
            out.append(_ac("ff-tiny", "lora", rank=r))
    # full-rank LoRA (r = d_model) note in §6.1.
    out.append(_ac("ff-tiny", "lora", rank=MODELS["ff-tiny"].d_model))
    # fig8: full-rank attention-only; pretraining substrate: full_all.
    out.append(_ac("ff-tiny", "full_attn"))
    for m in ("ff-tiny", "ff-small", "ff-medium", "ff-large"):
        out.append(_ac(m, "full_all"))
    # Pallas-kernel variant: proves the L1 kernel composes into the same HLO.
    out.append(_ac("ff-tiny", "lora", pallas=True))
    # e2e driver model.
    out.append(_ac("ff-xl", "lora"))
    return out


def smoke_artifact_set() -> List[ArtifactConfig]:
    """Minimal set for fast CI: tiny model, one low-rank + pallas variant."""
    return [_ac("ff-tiny", "lora"), _ac("ff-tiny", "lora", pallas=True)]


# ---------------------------------------------------------------------------
# Parameter spec — mirrored by rust/src/model/spec.rs.
# ---------------------------------------------------------------------------

ADAPTED_MATRICES = ("wq", "wk", "wv", "wo")


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    name: str
    shape: Tuple[int, ...]
    trainable: bool


def param_spec(ac: ArtifactConfig) -> List[ParamInfo]:
    """Canonical ordered parameter list for one artifact config.

    Order: embeddings, then per-layer (ln1, attention [+ adapters], ln2,
    mlp), then final LN and unembedding. Adapter params sit directly after
    the matrix they adapt. Programs take trainables first (in this order),
    then frozen params (in this order); ``manifest.json`` records both lists.
    """
    m = ac.model
    d, v, t, r = m.d_model, m.vocab_size, m.seq_len, ac.lora_rank
    mode = ac.train_mode
    full_all = mode == "full_all"
    out: List[ParamInfo] = []

    def p(name: str, *shape: int, trainable: bool = False) -> None:
        out.append(ParamInfo(name, tuple(shape), trainable or full_all))

    p("embed.tok", v, d)
    p("embed.pos", t, d)
    for i in range(m.n_layers):
        pre = f"layer{i}"
        p(f"{pre}.ln1.scale", d)
        p(f"{pre}.ln1.bias", d)
        for w in ADAPTED_MATRICES:
            p(f"{pre}.attn.{w}", d, d, trainable=(mode == "full_attn"))
            if mode in ("lora", "dora"):
                p(f"{pre}.attn.{w}.lora_a", d, r, trainable=True)
                p(f"{pre}.attn.{w}.lora_b", r, d, trainable=True)
            if mode == "dora":
                p(f"{pre}.attn.{w}.dora_m", d, trainable=True)
        p(f"{pre}.ln2.scale", d)
        p(f"{pre}.ln2.bias", d)
        p(f"{pre}.mlp.w_in", d, m.d_ff)
        p(f"{pre}.mlp.w_out", m.d_ff, d)
    p("final_ln.scale", d)
    p("final_ln.bias", d)
    p("unembed", d, v)
    return out


def trainable_spec(ac: ArtifactConfig) -> List[ParamInfo]:
    return [p for p in param_spec(ac) if p.trainable]


def frozen_spec(ac: ArtifactConfig) -> List[ParamInfo]:
    return [p for p in param_spec(ac) if not p.trainable]


def n_trainable(ac: ArtifactConfig) -> int:
    total = 0
    for p in trainable_spec(ac):
        n = 1
        for s in p.shape:
            n *= s
        total += n
    return total
