"""L2: GPT-style transformer forward/backward in JAX.

One function family per artifact config (see ``configs.ArtifactConfig``):

  * ``train_step``    — fused loss + grads + Adam update (fast path when the
    micro batch equals the global batch),
  * ``grad_step``     — loss + grads only (gradient-accumulation path; also
    the probe used by the Fig 6/12/13 analyses),
  * ``grad_accum``    — elementwise ``acc + g`` over the trainable set: the
    device-side micro-batch accumulator (per-micro gradients never visit
    the host),
  * ``grad_finalize`` — ``acc * inv_n``: scales the accumulated sum to the
    mean before ``adam_apply``,
  * ``adam_apply``    — Adam update from pre-accumulated grads,
  * ``eval_loss``     — mask-weighted mean loss (FF line search, test loss,
    Fig 5/8/10 loss-surface probes),
  * ``loft_realign``  — LoFT-style optimizer-state realignment: decays the
    Adam first moment by ``decay`` and the second moment by ``decay²``
    after each FF stage, so the moments forget the pre-extrapolation
    descent direction at matched per-coordinate step scale (the ``loft``
    optimizer backend; rust/src/train/engine.rs dispatches it).

Buffer donation: the programs in ``PROGRAM_DONATE`` are lowered with
``donate_argnums`` so the HLO carries an ``input_output_alias`` map and PJRT
reuses the donated input allocations for the aliased outputs in place (one
generation of accumulator/Adam state live per step instead of two). The
rust runtime mirrors the contract: donated inputs are consumed
(``Program::execute_raw_donated``) and must never be touched after the
call. ``train_step``/``grad_step``/``eval_loss`` are deliberately *not*
donated — their parameter inputs are long-lived device buffers that the
coordinator reuses across calls (see docs/transfer-contract.md).

Parameters are passed as *flat ordered lists* (trainables first, then
frozen), in exactly the order of ``configs.param_spec`` — the same order the
rust coordinator derives in ``rust/src/model/spec.rs`` and the manifest
records. No pytree magic crosses the language boundary.

Train modes:
  * ``lora``      — rank-r adapters on wq/wk/wv/wo (Hu et al., 2021); the
    adapted projection is ``x@W0 + s·(x@A)@B`` with s = α/r.
  * ``dora``      — magnitude/direction decomposition (Liu et al., 2024).
  * ``full_attn`` — attention matrices trained directly (paper Fig 8).
  * ``full_all``  — everything trainable (standard finetuning; also the
    pretraining substrate that manufactures W0 for the finetuning runs).
"""

from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from compile import contraction
from compile.configs import (ADAM_BETA1, ADAM_BETA2, ADAM_EPS, ArtifactConfig,
                             frozen_spec, trainable_spec)
from compile.kernels.lora_matmul import lora_matmul_batched
from compile.kernels.ref import dora_matmul_ref

DORA_EPS = 1e-6


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------

def pack_params(ac: ArtifactConfig, trainables: List[jax.Array],
                frozen: List[jax.Array]) -> Dict[str, jax.Array]:
    """Rebuild the name→array dict from the two flat lists."""
    tspec, fspec = trainable_spec(ac), frozen_spec(ac)
    assert len(trainables) == len(tspec), (len(trainables), len(tspec))
    assert len(frozen) == len(fspec), (len(frozen), len(fspec))
    params = {}
    for info, arr in zip(tspec, trainables):
        params[info.name] = arr
    for info, arr in zip(fspec, frozen):
        params[info.name] = arr
    return params


# ---------------------------------------------------------------------------
# Order-aware LoRA projection with an explicitly-ordered backward.
#
# The forward contraction order (``contraction.py``: factored ``(x·A)·B``
# vs merged ``x·(A·B)``) and the backward order are chosen *per shape* at
# trace time, so each program's HLO carries the analytic-FLOP-minimal
# chain and the manifest can record exactly what was emitted. The whole
# projection is a custom VJP — not autodiff — so the backward the FLOP
# model charges is the backward that actually runs (autodiff of the merged
# forward would route dx through the materialized A·B, a strictly worse
# order that the chooser never picks). The Pallas variant keeps its fused
# forward (FLOP-equivalent to factored; interpret-mode pallas_call lacks
# transpose rules — the flash-attention pattern) but shares the same
# order-selectable backward.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _lora_proj(x, w0, a, b, scale, orders, use_pallas):
    """x [..,K] @ (W0 + s·A·B) with ``orders = (fwd_order, bwd_order)``."""
    if use_pallas:
        return lora_matmul_batched(x, w0, a, b, scale)
    if orders[0] == contraction.MERGED:
        return x @ w0 + scale * (x @ (a @ b))
    return x @ w0 + scale * ((x @ a) @ b)


def _lora_proj_fwd(x, w0, a, b, scale, orders, use_pallas):
    return _lora_proj(x, w0, a, b, scale, orders, use_pallas), (x, w0, a, b)


def _lora_proj_bwd(scale, orders, use_pallas, res, g):
    x, w0, a, b = res
    x2 = x.reshape((-1, x.shape[-1]))
    g2 = g.reshape((-1, g.shape[-1]))
    dw0 = x2.T @ g2
    if orders[1] == contraction.MERGED:
        # Route dA/dB through the [K,N] intermediate G = xᵀ·g (== dw0, so
        # XLA computes it once); dx keeps the factored chain — merged dx
        # would cost 2MKN against factored 2Mr(K+N) and never wins.
        da = scale * (dw0 @ b.T)
        db = scale * (a.T @ dw0)
        dx2 = g2 @ w0.T + scale * ((g2 @ b.T) @ a.T)
    else:
        gb = g2 @ b.T
        dx2 = g2 @ w0.T + scale * (gb @ a.T)
        da = scale * (x2.T @ gb)
        db = scale * ((x2 @ a).T @ g2)
    return dx2.reshape(x.shape), dw0, da, db


_lora_proj.defvjp(_lora_proj_fwd, _lora_proj_bwd)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _proj_orders(ac: ArtifactConfig, x, w0):
    """Chosen (forward, backward) contraction orders for one projection,
    from the traced shapes — the same (M, K, N, r) that ``program_orders``
    feeds the chooser, so the manifest records exactly what was traced.
    The Pallas forward is fused (FLOP-equivalent to factored), so its
    forward order is pinned to factored for accounting."""
    m = 1
    for dim in x.shape[:-1]:
        m *= dim
    k, n, r = w0.shape[0], w0.shape[1], ac.lora_rank
    fwd = (contraction.FACTORED if ac.use_pallas
           else contraction.choose_forward(m, k, n, r))
    return (fwd, contraction.choose_backward(m, k, n, r))


def _proj(ac: ArtifactConfig, params, name: str, x):
    """Apply one (possibly adapted) attention projection: x [B,T,d] → [B,T,d]."""
    w0 = params[name]
    mode = ac.train_mode
    if mode in ("full_attn", "full_all"):
        return x @ w0
    a, b = params[f"{name}.lora_a"], params[f"{name}.lora_b"]
    if mode == "lora":
        orders = _proj_orders(ac, x, w0)
        return _lora_proj(x, w0, a, b, ac.lora_scale, orders, ac.use_pallas)
    assert mode == "dora"
    m = params[f"{name}.dora_m"]
    lead = x.shape[:-1]
    y = dora_matmul_ref(x.reshape((-1, x.shape[-1])), w0, a, b, m,
                        ac.lora_scale, eps=DORA_EPS)
    return y.reshape(lead + (w0.shape[1],))


def _attention(ac: ArtifactConfig, params, pre: str, x):
    bsz, t, d = x.shape
    h, dh = ac.model.n_heads, ac.model.d_head
    q = _proj(ac, params, f"{pre}.wq", x).reshape(bsz, t, h, dh)
    k = _proj(ac, params, f"{pre}.wk", x).reshape(bsz, t, h, dh)
    v = _proj(ac, params, f"{pre}.wv", x).reshape(bsz, t, h, dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, x.dtype))
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, None], scores, jnp.asarray(-1e30, x.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(bsz, t, d)
    return _proj(ac, params, f"{pre}.wo", out)


def forward(ac: ArtifactConfig, params: Dict[str, jax.Array], tokens):
    """tokens i32[B,T] → logits f32[B,T,V]."""
    t = tokens.shape[1]
    x = params["embed.tok"][tokens] + params["embed.pos"][None, :t]
    for i in range(ac.model.n_layers):
        pre = f"layer{i}"
        h = _layer_norm(x, params[f"{pre}.ln1.scale"], params[f"{pre}.ln1.bias"])
        x = x + _attention(ac, params, f"{pre}.attn", h)
        h = _layer_norm(x, params[f"{pre}.ln2.scale"], params[f"{pre}.ln2.bias"])
        x = x + jax.nn.gelu(h @ params[f"{pre}.mlp.w_in"]) @ params[f"{pre}.mlp.w_out"]
    x = _layer_norm(x, params["final_ln.scale"], params["final_ln.bias"])
    return x @ params["unembed"]


def masked_loss(logits, targets, mask):
    """Mask-weighted mean token cross-entropy (response-only loss for the
    instruction task arrives as zeros in the prompt region of ``mask``)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    return -(ll * mask).sum() / denom


def loss_fn(ac: ArtifactConfig, trainables, frozen, tokens, targets, mask):
    params = pack_params(ac, trainables, frozen)
    return masked_loss(forward(ac, params, tokens), targets, mask)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

def adam_update(trainables, m, v, step, grads, lr):
    """One Adam step with bias correction; ``step`` is the f32 count of
    steps already taken (the HLO mirrors rust/src/optim/adam.rs exactly)."""
    step1 = step + 1.0
    bc1 = 1.0 - jnp.power(jnp.asarray(ADAM_BETA1, jnp.float32), step1)
    bc2 = 1.0 - jnp.power(jnp.asarray(ADAM_BETA2, jnp.float32), step1)
    new_t, new_m, new_v = [], [], []
    for w, mm, vv, g in zip(trainables, m, v, grads):
        mm = ADAM_BETA1 * mm + (1.0 - ADAM_BETA1) * g
        vv = ADAM_BETA2 * vv + (1.0 - ADAM_BETA2) * (g * g)
        update = lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS)
        new_t.append(w - update)
        new_m.append(mm)
        new_v.append(vv)
    return new_t, new_m, new_v


# ---------------------------------------------------------------------------
# Program factories — each returns (fn, example_args) ready for jax.jit(...).lower
# ---------------------------------------------------------------------------

def _batch_examples(ac: ArtifactConfig, batch_size: int):
    t = ac.model.seq_len
    return (
        jax.ShapeDtypeStruct((batch_size, t), jnp.int32),   # tokens
        jax.ShapeDtypeStruct((batch_size, t), jnp.int32),   # targets
        jax.ShapeDtypeStruct((batch_size, t), jnp.float32),  # mask
    )


def _param_examples(spec):
    return [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in spec]


def make_train_step(ac: ArtifactConfig):
    def train_step(trainables, m, v, step, frozen, tokens, targets, mask, lr):
        loss, grads = jax.value_and_grad(
            lambda tr: loss_fn(ac, tr, frozen, tokens, targets, mask))(trainables)
        new_t, new_m, new_v = adam_update(trainables, m, v, step, grads, lr)
        return (loss, *new_t, *new_m, *new_v)

    tex = _param_examples(trainable_spec(ac))
    fex = _param_examples(frozen_spec(ac))
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    args = (tex, list(tex), list(tex), scalar, fex,
            *_batch_examples(ac, ac.model.micro_batch), scalar)
    return train_step, args


def make_grad_step(ac: ArtifactConfig):
    def grad_step(trainables, frozen, tokens, targets, mask):
        loss, grads = jax.value_and_grad(
            lambda tr: loss_fn(ac, tr, frozen, tokens, targets, mask))(trainables)
        return (loss, *grads)

    args = (_param_examples(trainable_spec(ac)),
            _param_examples(frozen_spec(ac)),
            *_batch_examples(ac, ac.model.micro_batch))
    return grad_step, args


def make_grad_accum(ac: ArtifactConfig):
    def grad_accum(acc, g):
        return tuple(a + b for a, b in zip(acc, g))

    tex = _param_examples(trainable_spec(ac))
    args = (tex, list(tex))
    return grad_accum, args


def make_grad_finalize(ac: ArtifactConfig):
    def grad_finalize(acc, inv_n):
        return tuple(a * inv_n for a in acc)

    tex = _param_examples(trainable_spec(ac))
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    args = (tex, scalar)
    return grad_finalize, args


def make_adam_apply(ac: ArtifactConfig):
    def adam_apply(trainables, m, v, step, grads, lr):
        new_t, new_m, new_v = adam_update(trainables, m, v, step, grads, lr)
        return (*new_t, *new_m, *new_v)

    tex = _param_examples(trainable_spec(ac))
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    args = (tex, list(tex), list(tex), scalar, list(tex), scalar)
    return adam_apply, args


def make_loft_realign(ac: ArtifactConfig):
    def loft_realign(m, v, decay):
        return (*(mm * decay for mm in m), *(vv * (decay * decay) for vv in v))

    tex = _param_examples(trainable_spec(ac))
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    args = (tex, list(tex), scalar)
    return loft_realign, args


def make_eval_loss(ac: ArtifactConfig):
    def eval_loss(trainables, frozen, tokens, targets, mask):
        return (loss_fn(ac, trainables, frozen, tokens, targets, mask),)

    args = (_param_examples(trainable_spec(ac)),
            _param_examples(frozen_spec(ac)),
            *_batch_examples(ac, ac.model.eval_batch))
    return eval_loss, args


# ---------------------------------------------------------------------------
# Batched multi-run variants — one program steps R stacked adapter runs.
#
# ``jax.vmap`` over the per-run state (adapters, optimizer state, step
# counts, batches, learning rates) with the frozen base broadcast
# (``in_axes=None``): R queued runs that share an artifact ride one
# dispatch per step and one resident W0 instead of R of each. Program
# names are ``{base}_batched{R}``; ``configs.programs_for`` decides which
# R values an artifact emits (LoRA only — the interpret-mode Pallas
# variant is a debugging reference, and full-rank runs stack nothing
# worth sharing). The per-run math inside the vmap is byte-for-byte the
# solo factory's body, which is what makes batched-vs-solo bit-identity
# a testable contract rather than a hope.
# ---------------------------------------------------------------------------

def _stacked(spec, runs):
    return [jax.ShapeDtypeStruct((runs,) + tuple(p.shape), jnp.float32)
            for p in spec]


def _batch_examples_stacked(ac: ArtifactConfig, runs: int, batch_size: int):
    t = ac.model.seq_len
    return (
        jax.ShapeDtypeStruct((runs, batch_size, t), jnp.int32),   # tokens
        jax.ShapeDtypeStruct((runs, batch_size, t), jnp.int32),   # targets
        jax.ShapeDtypeStruct((runs, batch_size, t), jnp.float32),  # mask
    )


def make_train_step_batched(ac: ArtifactConfig, runs: int):
    def train_step(trainables, m, v, step, frozen, tokens, targets, mask, lr):
        loss, grads = jax.value_and_grad(
            lambda tr: loss_fn(ac, tr, frozen, tokens, targets, mask))(trainables)
        new_t, new_m, new_v = adam_update(trainables, m, v, step, grads, lr)
        return (loss, *new_t, *new_m, *new_v)

    fn = jax.vmap(train_step, in_axes=(0, 0, 0, 0, None, 0, 0, 0, 0))
    tex = _stacked(trainable_spec(ac), runs)
    fex = _param_examples(frozen_spec(ac))
    vec = jax.ShapeDtypeStruct((runs,), jnp.float32)
    args = (tex, list(tex), list(tex), vec, fex,
            *_batch_examples_stacked(ac, runs, ac.model.micro_batch), vec)
    return fn, args


def make_grad_step_batched(ac: ArtifactConfig, runs: int):
    def grad_step(trainables, frozen, tokens, targets, mask):
        loss, grads = jax.value_and_grad(
            lambda tr: loss_fn(ac, tr, frozen, tokens, targets, mask))(trainables)
        return (loss, *grads)

    fn = jax.vmap(grad_step, in_axes=(0, None, 0, 0, 0))
    args = (_stacked(trainable_spec(ac), runs),
            _param_examples(frozen_spec(ac)),
            *_batch_examples_stacked(ac, runs, ac.model.micro_batch))
    return fn, args


def make_adam_apply_batched(ac: ArtifactConfig, runs: int):
    def adam_apply(trainables, m, v, step, grads, lr):
        new_t, new_m, new_v = adam_update(trainables, m, v, step, grads, lr)
        return (*new_t, *new_m, *new_v)

    fn = jax.vmap(adam_apply, in_axes=(0, 0, 0, 0, 0, 0))
    tex = _stacked(trainable_spec(ac), runs)
    vec = jax.ShapeDtypeStruct((runs,), jnp.float32)
    args = (tex, list(tex), list(tex), vec, list(tex), vec)
    return fn, args


def make_eval_loss_batched(ac: ArtifactConfig, runs: int):
    def eval_loss(trainables, frozen, tokens, targets, mask):
        return (loss_fn(ac, trainables, frozen, tokens, targets, mask),)

    fn = jax.vmap(eval_loss, in_axes=(0, None, 0, 0, 0))
    args = (_stacked(trainable_spec(ac), runs),
            _param_examples(frozen_spec(ac)),
            *_batch_examples_stacked(ac, runs, ac.model.eval_batch))
    return fn, args


PROGRAM_FACTORIES = {
    "train_step": make_train_step,
    "grad_step": make_grad_step,
    "grad_accum": make_grad_accum,
    "grad_finalize": make_grad_finalize,
    "adam_apply": make_adam_apply,
    "eval_loss": make_eval_loss,
    "loft_realign": make_loft_realign,
}

BATCHED_FACTORIES = {
    "train_step": make_train_step_batched,
    "grad_step": make_grad_step_batched,
    "adam_apply": make_adam_apply_batched,
    "eval_loss": make_eval_loss_batched,
}


def batched_runs(program: str):
    """Parse ``{base}_batched{R}`` → (base, R); None for solo programs."""
    if "_batched" not in program:
        return None
    base, _, suffix = program.rpartition("_batched")
    return base, int(suffix)


def program_factory(ac: ArtifactConfig, program: str):
    """(fn, example_args) for any program name, solo or ``*_batched{R}``."""
    parsed = batched_runs(program)
    if parsed is None:
        return PROGRAM_FACTORIES[program](ac)
    base, runs = parsed
    return BATCHED_FACTORIES[base](ac, runs)


# donate_argnums per program — *function-argument* positions (jax.jit
# semantics: a donated pytree argument donates all its leaves), NOT
# flattened leaf indices; ``donated_input_slots`` derives those for the
# manifest. Donating the grads into adam_apply frees their allocations
# during execution even though the greedy aliaser pairs the outputs with
# the matching t/m/v inputs first.
PROGRAM_DONATE = {
    "grad_accum": (0,),           # acc
    "grad_finalize": (0,),        # acc
    "adam_apply": (0, 1, 2, 4),   # trainables, m, v, grads
    "loft_realign": (0, 1),       # m, v
}

# Batched variants own their stacked state (one generation live per group
# step), so train_step_batched additionally donates t/m/v — unlike solo
# train_step, whose param inputs are the coordinator's long-lived buffers.
BATCHED_DONATE = {
    "train_step": (0, 1, 2),      # stacked trainables, m, v
    "adam_apply": (0, 1, 2, 4),   # stacked trainables, m, v, grads
}


def program_donate(program: str):
    """Donated argument positions for any program name."""
    parsed = batched_runs(program)
    if parsed is None:
        return PROGRAM_DONATE.get(program, ())
    return BATCHED_DONATE.get(parsed[0], ())


def donated_input_slots(ac: ArtifactConfig, program: str):
    """Flattened input-slot indices donated by ``program`` (manifest form
    of ``program_donate``: argument positions expanded to leaf positions)."""
    donate = program_donate(program)
    if not donate:
        return []
    _, args = program_factory(ac, program)
    slots, off = [], 0
    for i, a in enumerate(args):
        k = len(a) if isinstance(a, (list, tuple)) else 1
        if i in donate:
            slots.extend(range(off, off + k))
        off += k
    return slots


# ---------------------------------------------------------------------------
# Manifest I/O description (what the rust runtime cross-checks)
# ---------------------------------------------------------------------------

def _named(prefix, spec):
    return [{"name": f"{prefix}:{p.name}", "shape": list(p.shape),
             "dtype": "f32"} for p in spec]


def _batch_io(ac, batch):
    t = ac.model.seq_len
    return [
        {"name": "batch:tokens", "shape": [batch, t], "dtype": "i32"},
        {"name": "batch:targets", "shape": [batch, t], "dtype": "i32"},
        {"name": "batch:mask", "shape": [batch, t], "dtype": "f32"},
    ]


def _named_stacked(prefix, spec, runs):
    return [{"name": f"{prefix}:{p.name}", "shape": [runs] + list(p.shape),
             "dtype": "f32"} for p in spec]


def _batch_io_stacked(ac, runs, batch):
    t = ac.model.seq_len
    return [
        {"name": "batch:tokens", "shape": [runs, batch, t], "dtype": "i32"},
        {"name": "batch:targets", "shape": [runs, batch, t], "dtype": "i32"},
        {"name": "batch:mask", "shape": [runs, batch, t], "dtype": "f32"},
    ]


def _program_io_batched(ac: ArtifactConfig, base: str, runs: int):
    ts, fs = trainable_spec(ac), frozen_spec(ac)
    vec_f = lambda n: {"name": n, "shape": [runs], "dtype": "f32"}
    loss = vec_f("loss")
    st = lambda prefix: _named_stacked(prefix, ts, runs)
    if base == "train_step":
        ins = (st("t") + st("m") + st("v") + [vec_f("step")] + _named("f", fs)
               + _batch_io_stacked(ac, runs, ac.model.micro_batch)
               + [vec_f("lr")])
        outs = [loss] + st("t") + st("m") + st("v")
    elif base == "grad_step":
        ins = (st("t") + _named("f", fs)
               + _batch_io_stacked(ac, runs, ac.model.micro_batch))
        outs = [loss] + st("g")
    elif base == "adam_apply":
        ins = (st("t") + st("m") + st("v") + [vec_f("step")] + st("g")
               + [vec_f("lr")])
        outs = st("t") + st("m") + st("v")
    elif base == "eval_loss":
        ins = (st("t") + _named("f", fs)
               + _batch_io_stacked(ac, runs, ac.model.eval_batch))
        outs = [loss]
    else:
        raise ValueError(base)
    return ins, outs


def program_io(ac: ArtifactConfig, program: str):
    """(inputs, outputs) descriptors, in exact flattened order."""
    parsed = batched_runs(program)
    if parsed is not None:
        return _program_io_batched(ac, *parsed)
    ts, fs = trainable_spec(ac), frozen_spec(ac)
    scalar_f = lambda n: {"name": n, "shape": [], "dtype": "f32"}
    loss = {"name": "loss", "shape": [], "dtype": "f32"}
    if program == "train_step":
        ins = (_named("t", ts) + _named("m", ts) + _named("v", ts)
               + [scalar_f("step")] + _named("f", fs)
               + _batch_io(ac, ac.model.micro_batch) + [scalar_f("lr")])
        outs = [loss] + _named("t", ts) + _named("m", ts) + _named("v", ts)
    elif program == "grad_step":
        ins = (_named("t", ts) + _named("f", fs)
               + _batch_io(ac, ac.model.micro_batch))
        outs = [loss] + _named("g", ts)
    elif program == "grad_accum":
        ins = _named("acc", ts) + _named("g", ts)
        outs = _named("acc", ts)
    elif program == "grad_finalize":
        ins = _named("acc", ts) + [scalar_f("inv_n")]
        outs = _named("g", ts)
    elif program == "adam_apply":
        ins = (_named("t", ts) + _named("m", ts) + _named("v", ts)
               + [scalar_f("step")] + _named("g", ts) + [scalar_f("lr")])
        outs = _named("t", ts) + _named("m", ts) + _named("v", ts)
    elif program == "eval_loss":
        ins = (_named("t", ts) + _named("f", fs)
               + _batch_io(ac, ac.model.eval_batch))
        outs = [loss]
    elif program == "loft_realign":
        ins = _named("m", ts) + _named("v", ts) + [scalar_f("decay")]
        outs = _named("m", ts) + _named("v", ts)
    else:
        raise ValueError(program)
    return ins, outs


def program_orders(ac: ArtifactConfig, program: str):
    """Contraction orders the manifest records for ``program``: a dict with
    ``"forward"`` (and, for programs with a backward pass, ``"backward"``),
    or None when the program contains no LoRA matmul (non-LoRA artifacts,
    the pure-elementwise optimizer programs). Recomputes exactly what
    ``_proj_orders`` chose at trace time: every adapted projection is
    d×d (``configs.ADAPTED_MATRICES``), so one (M, K, N, r) shape — and
    one order pair — covers the whole program."""
    if ac.train_mode != "lora":
        return None
    parsed = batched_runs(program)
    base = parsed[0] if parsed else program
    if base in ("train_step", "grad_step"):
        batch = ac.model.micro_batch   # per-run batch, also under vmap
    elif base == "eval_loss":
        batch = ac.model.eval_batch
    else:
        return None                    # grad_accum/grad_finalize/adam_apply
    m = batch * ac.model.seq_len
    d, r = ac.model.d_model, ac.lora_rank
    fwd = (contraction.FACTORED if ac.use_pallas
           else contraction.choose_forward(m, d, d, r))
    orders = {"forward": fwd}
    if base != "eval_loss":
        orders["backward"] = contraction.choose_backward(m, d, d, r)
    return orders
